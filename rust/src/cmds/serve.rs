//! `clstm serve` — serve SynthTIMIT through the replicated stack engine.
//!
//! Serving always runs the **full stack topology**: `--model google`
//! chains both stacked layers, `--model small` chains two bidirectional
//! layers with concat joins (Fig 6b) — PER is computed over the complete
//! model, never a silently truncated layer 0.
//!
//! `--backend native` (default) runs everywhere with zero artifacts;
//! `--backend fxp` serves on the bit-accurate 16-bit datapath (§4.2) and
//! also serves the same workload on the float engine, so one command
//! reproduces the paper's float-vs-fixed accuracy comparison (`--q-format`
//! overrides the range-analysis data format; `--rounding truncate` swaps
//! every narrowing multiply to plain truncation for the §4.2 shift-policy
//! ablation at serve scale);
//! `--backend pjrt` executes the AOT artifacts and requires both the `pjrt`
//! cargo feature and a populated artifacts directory (`make artifacts`).
//!
//! Replication and load shape:
//!
//! - `--replicas N` or `--replicas MIN..MAX` — pipeline lanes sharing one
//!   prepared-weights copy; a range makes the engine elastic, growing and
//!   draining lanes from occupancy as the offered load swings;
//! - `--streams S` — utterance streams interleaved per lane;
//! - `--arrival closed|poisson` + `--rate R` — closed-loop (whole workload
//!   at t = 0) or open-loop Poisson arrivals at R utterances/second, which
//!   makes the queue-wait vs service split in the report meaningful;
//! - `--slo-ms B` — queue-wait SLO: deadline-aware admission sheds load so
//!   the *served* queue-wait tail stays within B ms under sustained
//!   overload (the summary reports the shed count and rate).
//!
//! Observability (see `DESIGN.md` §observability):
//!
//! - `--trace out.json` — record the full utterance lifecycle (arrival →
//!   admit/shed → dispatch → per-stage frame spans → completion, plus
//!   occupancy/shed/lane counter tracks) and export a Chrome
//!   `trace_event` document loadable in Perfetto / `chrome://tracing`;
//! - `--metrics-json out.json` — write the versioned machine-readable
//!   metrics snapshot (written atomically; validated by `clstm
//!   trace-check`);
//! - `--stats-interval S` — print a rolling `stats:` line (fps, frame
//!   p99, shed, lanes) every S seconds while serving.
//!
//! Fault tolerance (see `DESIGN.md` §fault-tolerance):
//!
//! - `--fault-inject seed:rate[:once|persistent]` — wrap the serving
//!   backend in the deterministic chaos harness ([`ChaosBackend`]): each
//!   built stage executor is faulty with probability `rate`, all draws
//!   seeded, so a chaos run is reproducible from its seed. Native and fxp
//!   backends only (the fxp float comparison run stays fault-free);
//! - `--restart-budget N` (default 2) — respawns allowed per dead lane
//!   before it is permanently retired (capacity degrades and the SLO
//!   shedder absorbs the overflow instead of the run erroring);
//! - `--retry-cap N` (default 2) — re-queues allowed per utterance
//!   reclaimed from a dead lane before it is counted as shed. With both
//!   budgets 0 serving is fail-stop: a dead lane aborts the run, the
//!   pre-fault-tolerance behavior.

use anyhow::Result;
use clstm::coordinator::server::{serve_workload_obs, Arrival, ServeOptions, ServeReport};
use clstm::coordinator::topology::StackTopology;
use clstm::lstm::config::LstmSpec;
use clstm::lstm::weights::LstmWeights;
use clstm::num::fxp::Rounding;
use clstm::obs::snapshot::{DatapathRow, MetricsSnapshot};
use clstm::obs::trace::{export_chrome_trace, TraceSink};
use clstm::obs::ObsOptions;
use clstm::runtime::backend::Backend;
use clstm::runtime::chaos::{ChaosBackend, ChaosMode};
use clstm::util::cli::{parse_fault_inject, parse_replicas, Cli};
use clstm::util::json::{write_atomic, Json};
use std::time::Duration;

/// `--fault-inject` resolved: chaos seed, per-executor fault rate, mode.
type ChaosParams = (u64, f64, ChaosMode);

/// Model spec + label for the serve run. Plain `clstm serve` uses the tiny
/// model; an explicit `--model google|small --k <k>` serves the paper-scale
/// models with random weights (throughput demo).
fn serve_spec(cli: &Cli) -> (String, LstmSpec) {
    let model = cli.get_str("model");
    let k = cli.get_usize("k");
    if model == "tiny" || !cli.is_set("model") {
        ("tiny_fft4".to_string(), LstmSpec::tiny(4))
    } else {
        let spec = match model.as_str() {
            "small" => LstmSpec::small(k),
            _ => LstmSpec::google(k),
        };
        (format!("{model}_fft{k}"), spec)
    }
}

/// Golden trained weights when serving the tiny config with artifacts
/// present (gives a real PER); random init otherwise (throughput demo).
fn load_serve_weights(cli: &Cli, label: &str, spec: &LstmSpec) -> LstmWeights {
    if label == "tiny_fft4" {
        use clstm::runtime::artifact::ArtifactDir;
        use std::path::Path;
        let art_dir = cli.get_str("artifacts");
        if let Ok(art) = ArtifactDir::open(Path::new(&art_dir)) {
            if let Some(golden) = art.golden_weights.as_ref() {
                if let Ok(w) = LstmWeights::load(golden) {
                    println!("using golden tiny weights from {art_dir}");
                    return w;
                }
            }
        }
    }
    LstmWeights::random(spec, cli.get_u64("seed"))
}

/// Translate the CLI flags into engine/serve options.
fn serve_options(cli: &Cli) -> Result<ServeOptions> {
    let arrival = match cli.get_str("arrival").as_str() {
        "closed" => Arrival::Closed,
        "poisson" => Arrival::Poisson {
            rate: cli.get_f64("rate"),
        },
        other => anyhow::bail!("unknown --arrival {other:?} (expected: closed | poisson)"),
    };
    let (replicas, max_replicas) =
        parse_replicas(&cli.get_str("replicas")).map_err(anyhow::Error::msg)?;
    let slo_ms = cli.get_f64("slo-ms");
    anyhow::ensure!(slo_ms >= 0.0 && slo_ms.is_finite(), "--slo-ms must be ≥ 0");
    Ok(ServeOptions {
        replicas,
        max_replicas,
        streams_per_lane: cli.get_usize("streams"),
        arrival,
        seed: cli.get_u64("seed"),
        slo: (slo_ms > 0.0).then(|| Duration::from_secs_f64(slo_ms / 1e3)),
        restart_budget: cli.get_usize("restart-budget").min(u32::MAX as usize) as u32,
        retry_cap: cli.get_usize("retry-cap").min(u32::MAX as usize) as u32,
        ..ServeOptions::default()
    })
}

/// Parse `--rounding nearest|truncate` (fxp-only, like `--q-format`).
fn parse_rounding(cli: &Cli) -> Result<Rounding> {
    match cli.get_str("rounding").as_str() {
        "nearest" => Ok(Rounding::Nearest),
        "truncate" => Ok(Rounding::Truncate),
        other => anyhow::bail!("unknown --rounding {other:?} (expected: nearest | truncate)"),
    }
}

/// Translate `--trace` / `--stats-interval` into [`ObsOptions`]: an enabled
/// sink only when a trace path was given, so the default serve stays on the
/// zero-cost disabled path.
fn obs_options(cli: &Cli) -> Result<ObsOptions> {
    let stats_s = cli.get_f64("stats-interval");
    anyhow::ensure!(
        stats_s >= 0.0 && stats_s.is_finite(),
        "--stats-interval must be ≥ 0 seconds"
    );
    Ok(ObsOptions {
        trace: if cli.get_nonempty("trace").is_some() {
            TraceSink::enabled()
        } else {
            TraceSink::disabled()
        },
        stats_interval: (stats_s > 0.0).then(|| Duration::from_secs_f64(stats_s)),
    })
}

pub fn serve_cmd(cli: &Cli) -> Result<()> {
    let (label, spec) = serve_spec(cli);
    let weights = load_serve_weights(cli, &label, &spec);
    let n_utts = cli.get_usize("utts");
    let opts = serve_options(cli)?;
    let obs = obs_options(cli)?;

    // --q-format/--rounding drive the fxp datapath only; validate them up
    // front so a typo'd or misplaced option errors on every backend
    // instead of being silently ignored.
    let backend_name = cli.get_str("backend");
    let q_override = cli.get_q_format("q-format").map_err(anyhow::Error::msg)?;
    if q_override.is_some() && backend_name != "fxp" {
        anyhow::bail!("--q-format applies to --backend fxp only (got --backend {backend_name})");
    }
    let rounding = parse_rounding(cli)?;
    if rounding != Rounding::Nearest && backend_name != "fxp" {
        anyhow::bail!("--rounding applies to --backend fxp only (got --backend {backend_name})");
    }
    // Resolve --fault-inject up front so a malformed spec errors before any
    // weights are prepared, whatever the backend.
    let chaos_params: Option<ChaosParams> = match cli.get_nonempty("fault-inject") {
        Some(s) => {
            let (seed, rate, persistent) = parse_fault_inject(&s).map_err(anyhow::Error::msg)?;
            let mode = if persistent { ChaosMode::Persistent } else { ChaosMode::Once };
            anyhow::ensure!(
                backend_name == "native" || backend_name == "fxp",
                "--fault-inject supports --backend native | fxp (got --backend {backend_name})"
            );
            Some((seed, rate, mode))
        }
        None => None,
    };

    // Every serve path runs the complete stack topology — print the DAG so
    // multi-layer / bidirectional runs say exactly what is being chained.
    let topo = StackTopology::compile(&spec);
    println!("  topology: {}", topo.describe());

    let report: ServeReport = match backend_name.as_str() {
        "pjrt" => serve_pjrt(cli, &label, &weights, n_utts, &opts, &obs)?,
        "native" => {
            use clstm::runtime::native::NativeBackend;
            println!(
                "serving {label} on the native backend: {n_utts} utterances, \
                 {} replica(s) × {} streams, {:?} arrivals ...",
                opts.replicas, opts.streams_per_lane, opts.arrival
            );
            serve_maybe_chaos(NativeBackend::default(), chaos_params, &weights, n_utts, &opts, &obs)?
        }
        "fxp" => serve_fxp(
            q_override,
            rounding,
            chaos_params,
            &label,
            &weights,
            n_utts,
            &opts,
            &obs,
        )?,
        other => anyhow::bail!(
            "unknown --backend {other:?} (expected: {})",
            clstm::runtime::backend::backend_names()
        ),
    };
    println!("  backend: {} ({} replicas)", report.config, report.replicas);
    println!("  {}", report.metrics.summary());
    if let Some(slo) = report.slo {
        // Served-tail SLO check: queue-wait p99 covers *served* utterances
        // only (shed ones never reach the engine), which is exactly the
        // population the SLO governs.
        let slo_ms = slo.as_secs_f64() * 1e3;
        let p99_ms = report.metrics.queue_wait_p99_us() / 1e3;
        println!(
            "  SLO {:.0}ms: served queue-wait p99 {:.1}ms ≤ {:.1}ms ({}); shed {}/{} ({:.1}%)",
            slo_ms,
            p99_ms,
            slo_ms,
            if p99_ms <= slo_ms { "met" } else { "missed" },
            report.metrics.shed,
            report.metrics.offered,
            report.metrics.shed_rate() * 100.0
        );
    }
    println!("  workload PER: {:.2}% (full {}-layer stack)", report.per, spec.layers);

    if let Some(path) = cli.get_nonempty("trace") {
        // Every worker has flushed by now (the engine was dropped inside
        // the serve loop), so the export sees the complete recording.
        let meta = vec![
            ("kind", Json::str("clstm-trace")),
            ("backend", Json::str(report.config.clone())),
            ("model", Json::str(label.clone())),
            ("replicas", Json::num(report.replicas as f64)),
        ];
        let doc = export_chrome_trace(&obs.trace, meta)
            .expect("--trace implies an enabled sink");
        let events = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .map_or(0, Vec::len);
        write_atomic(&path, &doc.to_string())?;
        println!("  trace: {path} ({events} events)");
    }
    if let Some(path) = cli.get_nonempty("metrics-json") {
        let snap = build_snapshot(&report, &label);
        snap.write(&path)?;
        println!("  metrics snapshot: {path}");
    }
    Ok(())
}

/// Serve on `backend`, wrapped in the seeded chaos harness when
/// `--fault-inject` was given. The chaos wrapper's fired-fault count is
/// lifted into the report's metrics (so the summary line and the snapshot
/// `faults` block carry it) and the planned-site count is printed — a
/// vacuous chaos run (zero sites drawn) is visible at a glance.
fn serve_maybe_chaos(
    backend: impl Backend,
    chaos_params: Option<ChaosParams>,
    weights: &LstmWeights,
    n_utts: usize,
    opts: &ServeOptions,
    obs: &ObsOptions,
) -> Result<ServeReport> {
    let Some((seed, rate, mode)) = chaos_params else {
        return serve_workload_obs(&backend, weights, n_utts, opts, obs);
    };
    let chaos = ChaosBackend::new(backend, seed, rate, mode);
    let mut report = serve_workload_obs(&chaos, weights, n_utts, opts, obs)?;
    report.metrics.faults_injected = chaos.injected();
    println!(
        "  chaos: seed {seed:#x}, rate {rate}, {mode:?} — {} fault sites planned, {} fired",
        chaos.plan().len(),
        chaos.injected()
    );
    Ok(report)
}

/// Lift a [`ServeReport`] into the versioned snapshot (identity fields,
/// SLO verdict, fxp datapath watermarks included).
fn build_snapshot(report: &ServeReport, label: &str) -> MetricsSnapshot {
    let mut snap = MetricsSnapshot::from_metrics(&report.metrics);
    snap.backend = report.config.clone();
    snap.model = label.to_string();
    snap.replicas = report.replicas;
    snap.per_pct = Some(report.per);
    if let Some(slo) = report.slo {
        let slo_ms = slo.as_secs_f64() * 1e3;
        snap.slo_ms = Some(slo_ms);
        // Same served-tail check the human summary prints.
        snap.slo_met = Some(report.metrics.queue_wait_p99_us() / 1e3 <= slo_ms);
    }
    snap.datapath = report
        .datapath
        .iter()
        .map(|(segment, forward_calls, forward_peak, acc_peak, time_peak)| DatapathRow {
            segment: segment.clone(),
            forward_calls: *forward_calls,
            forward_peak: *forward_peak,
            acc_peak: *acc_peak,
            time_peak: *time_peak,
        })
        .collect();
    snap
}

/// Serve on the 16-bit fixed-point backend, then serve the identical
/// workload (same seed) on the float engine — the §4.2 float-vs-fixed
/// accuracy comparison in one command.
#[allow(clippy::too_many_arguments)]
fn serve_fxp(
    q_override: Option<clstm::num::fxp::Q>,
    rounding: Rounding,
    chaos_params: Option<ChaosParams>,
    label: &str,
    weights: &LstmWeights,
    n_utts: usize,
    opts: &ServeOptions,
    obs: &ObsOptions,
) -> Result<ServeReport> {
    use clstm::coordinator::server::serve_workload;
    use clstm::runtime::fxp::{FxpBackend, FXP_PER_DEGRADATION_BUDGET_PTS};
    use clstm::runtime::native::NativeBackend;

    // Resolve the data format once (the auto path scans every weight
    // tensor) and hand the backend the resolved format, so `prepare`
    // doesn't repeat the range analysis.
    let q = q_override.unwrap_or_else(|| FxpBackend::recommend_q(weights));
    let backend = FxpBackend {
        q: Some(q),
        rounding,
        ..Default::default()
    };
    println!(
        "serving {label} on the fxp backend (Q{}.{} 16-bit datapath{}, {} narrowing): \
         {n_utts} utterances, {} replica(s) × {} streams, {:?} arrivals ...",
        15 - q.frac,
        q.frac,
        if q_override.is_some() {
            ""
        } else {
            ", range-analysis recommendation"
        },
        match rounding {
            Rounding::Nearest => "round-to-nearest",
            Rounding::Truncate => "truncate",
        },
        opts.replicas,
        opts.streams_per_lane,
        opts.arrival
    );
    // Observability (and, under --fault-inject, the chaos harness) rides
    // on the primary (fxp) run only — the float comparison below is a
    // plain, fault-free accuracy reference.
    let report = serve_maybe_chaos(backend, chaos_params, weights, n_utts, opts, obs)?;

    // §4.2 comparison: the same seeded workload through the float engine.
    let float = serve_workload(&NativeBackend::default(), weights, n_utts, opts)?;
    println!("  float-vs-fixed (§4.2):");
    println!("    f32 PER: {:.2}%   fxp PER: {:.2}%", float.per, report.per);
    println!(
        "    degradation: {:+.2} points (budget: ≤ {FXP_PER_DEGRADATION_BUDGET_PTS})",
        report.per - float.per
    );
    Ok(report)
}

#[cfg(feature = "pjrt")]
fn serve_pjrt(
    cli: &Cli,
    label: &str,
    weights: &LstmWeights,
    n_utts: usize,
    opts: &ServeOptions,
    obs: &ObsOptions,
) -> Result<ServeReport> {
    use anyhow::Context;
    use clstm::coordinator::server::serve_workload_obs;
    use clstm::runtime::artifact::ArtifactDir;
    use clstm::runtime::client::Runtime;
    use clstm::runtime::pjrt::PjrtBackend;
    use std::path::Path;

    let art_dir = cli.get_str("artifacts");
    let art = ArtifactDir::open(Path::new(&art_dir))
        .with_context(|| format!("opening artifacts in {art_dir} (run `make artifacts`)"))?;
    let rt = Runtime::cpu()?;
    println!(
        "serving {label} on PJRT ({}) with {n_utts} utterances / {} replica(s) ...",
        rt.platform(),
        opts.replicas
    );
    let backend = PjrtBackend::new(rt, art, label.to_string());
    serve_workload_obs(&backend, weights, n_utts, opts, obs)
}

#[cfg(not(feature = "pjrt"))]
fn serve_pjrt(
    _cli: &Cli,
    _label: &str,
    _weights: &LstmWeights,
    _n_utts: usize,
    _opts: &ServeOptions,
    _obs: &ObsOptions,
) -> Result<ServeReport> {
    anyhow::bail!(
        "the pjrt backend requires building with `cargo build --features pjrt` \
         (and `make artifacts`); the default build serves on the native backend"
    )
}
