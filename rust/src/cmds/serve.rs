//! `clstm serve` — serve SynthTIMIT through the PJRT pipeline.

use anyhow::{Context, Result};
use clstm::coordinator::server::serve_workload;
use clstm::lstm::config::LstmSpec;
use clstm::lstm::weights::LstmWeights;
use clstm::runtime::artifact::ArtifactDir;
use clstm::runtime::client::Runtime;
use clstm::util::cli::Cli;
use std::path::Path;

pub fn serve_cmd(cli: &Cli) -> Result<()> {
    let art_dir = cli.get_str("artifacts");
    let art = ArtifactDir::open(Path::new(&art_dir))
        .with_context(|| format!("opening artifacts in {art_dir} (run `make artifacts`)"))?;

    // Serve the tiny config by default (its golden weights ship with the
    // artifacts); `--model google --k 8` serves google_fft8 with random
    // weights (throughput demo).
    let model = cli.get_str("model");
    let k = cli.get_usize("k");
    let (config_name, weights) = if model == "tiny" || cli.positional().len() < 2 {
        let w = LstmWeights::load(
            &art.golden_weights
                .clone()
                .context("golden weights missing from artifacts")?,
        )?;
        ("tiny_fft4".to_string(), w)
    } else {
        let spec = match model.as_str() {
            "small" => LstmSpec::small(k),
            _ => LstmSpec::google(k),
        };
        (
            format!("{model}_fft{k}"),
            LstmWeights::random(&spec, cli.get_u64("seed")),
        )
    };

    let rt = Runtime::cpu()?;
    println!(
        "serving {} on PJRT ({}) with {} utterances / {} streams ...",
        config_name,
        rt.platform(),
        cli.get_usize("utts"),
        cli.get_usize("streams")
    );
    let report = serve_workload(
        rt,
        &art,
        &config_name,
        &weights,
        cli.get_usize("utts"),
        cli.get_usize("streams"),
    )?;
    println!("  {}", report.metrics.summary());
    println!("  workload PER: {:.2}%", report.per);
    Ok(())
}
