//! Table/flow subcommands: table1, table3, schedule, dse, codegen, simulate.

use anyhow::{Context, Result};
use clstm::dse::explore;
use clstm::fpga_sim::simulate;
use clstm::graph::builder::build_layer_graph;
use clstm::hlscodegen::generate_design;
use clstm::lstm::config::LstmSpec;
use clstm::perfmodel::platform::Platform;
use clstm::report::tables as rt;
use clstm::schedule::algorithm1::schedule;
use clstm::schedule::replication::enumerate_replication;
use clstm::util::cli::Cli;

pub fn spec_from(cli: &Cli) -> LstmSpec {
    let k = cli.get_usize("k");
    match cli.get_str("model").as_str() {
        "small" => LstmSpec::small(k),
        "tiny" => LstmSpec::tiny(k),
        _ => LstmSpec::google(k),
    }
}

pub fn platform_from(cli: &Cli) -> Platform {
    match cli.get_str("platform").as_str() {
        "7v3" | "adm7v3" => Platform::adm7v3(),
        _ => Platform::ku060(),
    }
}

pub fn table1(cli: &Cli) -> Result<()> {
    let path = std::path::Path::new(&cli.get_str("artifacts")).join("table1.json");
    let json = std::fs::read_to_string(&path).ok();
    rt::table1(json.as_deref()).print();
    if json.is_none() {
        println!(
            "\n(PER column pending — run `make table1-per` to train the sweep; \
             looked for {})",
            path.display()
        );
    }
    Ok(())
}

pub fn table3(_cli: &Cli) -> Result<()> {
    let (t, ratios) = rt::table3();
    t.print();
    println!("\n§6.2/§6.3 headline ratios vs ESE (7V3, KU060-bounded):");
    for r in ratios {
        println!("  {r}");
    }
    Ok(())
}

pub fn schedule_cmd(cli: &Cli) -> Result<()> {
    let spec = spec_from(cli);
    let plat = platform_from(cli);
    let g = build_layer_graph(&spec, 0);
    let s = enumerate_replication(schedule(&g, &plat.budget()), &plat.budget());
    println!(
        "Algorithm 1 on {} (k={}) for {}:\n{}",
        spec.kind.as_str(),
        spec.k,
        plat.name,
        s.describe()
    );
    let res = s.resources();
    let u = plat.utilisation(&res);
    println!(
        "resources: DSP {:.1}%  BRAM {:.1}%  LUT {:.1}%  FF {:.1}%",
        u.dsp, u.bram, u.lut, u.ff
    );
    Ok(())
}

pub fn dse_cmd(cli: &Cli) -> Result<()> {
    let plat = platform_from(cli);
    let base = spec_from(cli);
    let pts = explore(&base, &plat, &[2, 4, 8, 16]);
    println!("design-space exploration ({}, {}):", base.kind.as_str(), plat.name);
    println!(
        "{:>4} {:>12} {:>12} {:>9} {:>8} {:>8}",
        "k", "FPS", "latency µs", "power W", "FPS/W", "DSP%"
    );
    for p in &pts {
        println!(
            "{:>4} {:>12.0} {:>12.2} {:>9.1} {:>8.0} {:>8.1}",
            p.spec.k,
            p.perf.fps,
            p.perf.latency_us,
            p.power_w,
            p.fps_per_watt,
            p.utilisation.dsp
        );
    }
    Ok(())
}

pub fn codegen_cmd(cli: &Cli) -> Result<()> {
    let spec = spec_from(cli);
    let plat = platform_from(cli);
    let g = build_layer_graph(&spec, 0);
    let s = enumerate_replication(schedule(&g, &plat.budget()), &plat.budget());
    let name = format!("{}_fft{}", spec.kind.as_str(), spec.k);
    let src = generate_design(&s, &name);
    let out = cli.get_str("out");
    if out.is_empty() {
        println!("{src}");
    } else {
        std::fs::write(&out, &src).with_context(|| format!("writing {out}"))?;
        println!("wrote {} bytes of HLS C++ to {out}", src.len());
    }
    Ok(())
}

pub fn simulate_cmd(cli: &Cli) -> Result<()> {
    let spec = spec_from(cli);
    let plat = platform_from(cli);
    let g = build_layer_graph(&spec, 0);
    let s = enumerate_replication(schedule(&g, &plat.budget()), &plat.budget());
    let frames = 256;
    let sim = simulate(&s, frames);
    let clk_us = 1e6 / plat.freq_hz;
    println!(
        "discrete-event simulation, {} frames ({} k={}, {}):",
        frames,
        spec.kind.as_str(),
        spec.k,
        plat.name
    );
    println!(
        "  steady II: {} cycles = {:.2} µs  ->  {:.0} FPS",
        sim.ii_cycles,
        sim.ii_cycles as f64 * clk_us,
        plat.freq_hz / sim.ii_cycles as f64
    );
    println!(
        "  fill latency: {:.2} µs; steady latency: {:.2} µs",
        sim.latency[0] as f64 * clk_us,
        sim.steady_latency_cycles() * clk_us
    );
    for (i, occ) in sim.occupancy.iter().enumerate() {
        println!("  stage {} occupancy: {:.1}%", i + 1, occ * 100.0);
    }
    Ok(())
}
