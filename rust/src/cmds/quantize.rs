//! `clstm quantize` — the §4.2 bit-accurate quantisation study: range
//! analysis, Q-format recommendation, float-vs-fixed engine comparison, and
//! the shift-policy ablation.

use anyhow::Result;
use clstm::data::per::phone_error_rate;
use clstm::data::synth::{SynthConfig, SynthTimit};
use clstm::lstm::activations::ActivationMode;
use clstm::lstm::config::LstmSpec;
use clstm::lstm::sequence::{StackF32, StackFx};
use clstm::lstm::weights::LstmWeights;
use clstm::num::fxp::Q;
use clstm::quant::range::RangeTracker;
use clstm::util::cli::Cli;

pub fn quantize_cmd(cli: &Cli) -> Result<()> {
    // Scaled model so the full study runs in seconds.
    let k = cli.get_usize("k");
    let spec = LstmSpec {
        hidden_dim: 64,
        proj_dim: Some(32),
        input_dim: 24,
        num_classes: 12,
        ..LstmSpec::tiny(k.max(2))
    };
    let weights = LstmWeights::random(&spec, cli.get_u64("seed"));
    let synth = SynthTimit::new(SynthConfig {
        n_phones: spec.num_classes,
        base_dim: spec.input_dim / 3 - 1,
        mean_frames: 50,
        ..SynthConfig::tiny()
    });
    let utts = synth.batch(1, 8);
    let frames: Vec<Vec<Vec<f32>>> = utts
        .iter()
        .map(|u| {
            u.frames
                .iter()
                .map(|f| {
                    let mut v = f.clone();
                    v.truncate(spec.input_dim);
                    v.resize(spec.input_dim, 0.0);
                    v
                })
                .collect()
        })
        .collect();

    // Range analysis over the float engine's tensors.
    let float = StackF32::new(&weights, ActivationMode::Pwl);
    let mut tracker = RangeTracker::new();
    for f in &frames {
        for frame in f {
            tracker.observe("input", frame);
        }
        for out in float.run(f) {
            tracker.observe("output_y", &out);
        }
    }
    let report = tracker.report(1);
    println!("range analysis (§4.2):\n{}", report.to_table());
    let q = report.datapath_format();
    println!("selected datapath format: Q{}.{}", 15 - q.frac, q.frac);

    // Accuracy: float vs bit-accurate 16-bit engine, end to end.
    let refs: Vec<Vec<usize>> = utts.iter().map(|u| u.phone_seq()).collect();
    let float_hyps: Vec<Vec<usize>> = frames.iter().map(|f| float.decode(f)).collect();
    let fx = StackFx::new(&weights, q);
    let fx_hyps: Vec<Vec<usize>> = frames.iter().map(|f| fx.decode(f)).collect();
    let per_f = phone_error_rate(&float_hyps, &refs);
    let per_x = phone_error_rate(&fx_hyps, &refs);
    println!("\nPER float engine:      {per_f:.2}%");
    println!("PER 16-bit fxp engine: {per_x:.2}%  (degradation {:+.2})", per_x - per_f);
    println!("(paper §4.2: \"16-bit fixed point is accurate enough\")");

    // Agreement between the engines framewise.
    let mut agree = 0usize;
    let mut total = 0usize;
    for (a, b) in float_hyps.iter().zip(&fx_hyps) {
        agree += a.iter().zip(b).filter(|(x, y)| x == y).count();
        total += a.len();
    }
    println!(
        "framewise decision agreement: {:.2}%",
        100.0 * agree as f64 / total as f64
    );

    // Shift-policy ablation (the Fig/§4.2 argument).
    use clstm::fft::fxp::{roundtrip_rms_eps, FxFftPlan, ShiftPolicy};
    use clstm::util::prng::Xoshiro256;
    let mut rng = Xoshiro256::seed_from_u64(3);
    let n = 16;
    println!("\nFFT shift-policy ablation (n={n}, Q{}.{}, truncating shifts):", 15 - 12, 12);
    for (policy, label) in [
        (ShiftPolicy::IdftAtEnd, "shift log2(k) bits at IDFT end"),
        (ShiftPolicy::IdftDistributed, "1 bit per IDFT stage"),
        (ShiftPolicy::DftDistributed, "1 bit per DFT stage (paper)"),
    ] {
        let plan = FxFftPlan::new(n, policy, clstm::num::fxp::Rounding::Truncate);
        let mut rms = 0.0;
        let qd = Q::new(12);
        for _ in 0..200 {
            let x: Vec<f64> = (0..n).map(|_| rng.uniform(-0.4, 0.4)).collect();
            rms += roundtrip_rms_eps(&plan, qd, &x);
        }
        println!("  {label:<36} roundtrip rms {:.2} LSB", rms / 200.0);
    }
    Ok(())
}
