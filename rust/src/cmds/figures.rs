//! Figure subcommands.

use anyhow::Result;
use clstm::report::figures as rf;
use clstm::util::cli::Cli;

pub fn fig3(cli: &Cli) -> Result<()> {
    rf::fig3(cli.get_usize("k")).print();
    Ok(())
}

pub fn fig4(_cli: &Cli) -> Result<()> {
    rf::fig4().print();
    Ok(())
}

pub fn fig5(cli: &Cli) -> Result<()> {
    rf::fig5(cli.get_usize("k")).print();
    Ok(())
}

pub fn fig6(cli: &Cli) -> Result<()> {
    let (t, dot) = rf::fig6(cli.get_usize("k"));
    t.print();
    let out = cli.get_str("out");
    if !out.is_empty() {
        std::fs::write(&out, dot)?;
        println!("(wrote operator graph dot to {out})");
    }
    Ok(())
}
