// `std::simd` (portable SIMD) is nightly-only; the non-default `simd`
// feature opts into it for the vectorized spectral kernels (num/simd.rs).
// Stable builds compile the bit-identical scalar twins instead.
#![cfg_attr(feature = "simd", feature(portable_simd))]

//! # C-LSTM — structured LSTM compression + FPGA synthesis framework
//!
//! A full reproduction of *C-LSTM: Enabling Efficient LSTM using Structured
//! Compression Techniques on FPGAs* (Wang et al., FPGA'18) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **Layer 1** (build-time Python): a Pallas kernel computing the FFT-domain
//!   block-circulant mat-vec, `a_i = IDFT(Σ_j F(w_ij) ⊙ F(x_j))`.
//! - **Layer 2** (build-time Python): the Google-LSTM / Small-LSTM compute
//!   graphs in JAX, AOT-lowered to HLO text in `artifacts/`.
//! - **Layer 3** (this crate): the entire C-LSTM *framework* — operator graph
//!   generation, Algorithm-1 scheduling, analytical performance/resource
//!   models (Eq 7–12), design-space exploration, HLS code generation, a
//!   cycle-approximate FPGA pipeline simulator, the ESE sparse baseline, a
//!   bit-accurate 16-bit fixed-point inference engine, and a replicated
//!   stack-topology serving engine (full multi-layer / bidirectional
//!   models as chained per-(layer, direction) pipeline segments — Fig 6b —
//!   with N topology instances sharing one prepared-weights copy,
//!   continuous admission) over pluggable runtime backends: the default
//!   **native** backend executes the pipeline with the crate's own engines
//!   (zero external artifacts), while the optional `pjrt` cargo feature
//!   runs the AOT artifacts through PJRT.
//!
//! Layers 1–2 are build-time only: a fresh checkout builds and serves with
//! default features and no Python step. See `DESIGN.md` (repo root) for the
//! system inventory, the `default`/`pjrt` feature matrix, and the build +
//! `make artifacts` instructions.

pub mod analysis;
pub mod circulant;
pub mod coordinator;
pub mod data;
pub mod dse;
pub mod ese;
pub mod fft;
pub mod fpga_sim;
pub mod graph;
pub mod hlscodegen;
pub mod lstm;
pub mod num;
pub mod obs;
pub mod perfmodel;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI and the serving coordinator.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
