//! Deterministic pseudo-random number generation.
//!
//! `rand` is not available offline, so we implement the two small generators
//! the crate needs:
//!
//! - [`SplitMix64`] — used to seed other generators (one multiply/xor chain
//!   per draw; passes BigCrush when used as a seeder).
//! - [`Xoshiro256`] — xoshiro256**, the general-purpose generator used by
//!   weight init, the synthetic dataset, pruning, and the property-testing
//!   harness. Deterministic under a fixed seed on every platform.
//!
//! All draws are reproducible: every experiment harness seeds explicitly and
//! records the seed in its report.

/// SplitMix64: stateless-feeling 64-bit generator, primarily used to expand
/// a single user seed into the 256-bit state of [`Xoshiro256`].
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    // Wrapping mod-2^64 arithmetic is the SplitMix64 algorithm itself, not
    // an overflow hazard — exempt from the crate-wide wrapping-op ban.
    #[allow(clippy::disallowed_methods)]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna). Fast, 256-bit state, equidistributed
/// in 4 dimensions; the workhorse PRNG of this crate.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
    /// Cached second normal draw from the Box–Muller pair.
    gauss_spare: Option<f64>,
}

impl Xoshiro256 {
    /// Seed via SplitMix64 expansion (recommended by the xoshiro authors).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Next 64 uniformly distributed bits.
    // The xoshiro256** scrambler is defined over mod-2^64 arithmetic —
    // exempt from the crate-wide wrapping-op ban.
    #[allow(clippy::disallowed_methods)]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 bits.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` using Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal draw via Box–Muller (caches the pair's second value).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal draw with the given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted_index needs a positive total");
        let mut r = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if r < w {
                return i;
            }
            r -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (from the public-domain C impl).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut a = Xoshiro256::seed_from_u64(7);
        let mut b = Xoshiro256::seed_from_u64(7);
        let mut c = Xoshiro256::seed_from_u64(8);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough_and_in_range() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let n = 10u64;
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            let v = rng.below(n);
            assert!(v < n);
            counts[v as usize] += 1;
        }
        // Each bucket should be within 10% of the expectation (10k).
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0u32; 3];
        for _ in 0..40_000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "ratio {ratio}");
    }
}
