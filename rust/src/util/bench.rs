//! Criterion-style benchmark harness (the offline stand-in for `criterion`).
//!
//! Every `cargo bench` target in `rust/benches/` uses this: warmup, timed
//! iterations with outlier-robust statistics (mean / p50 / p95 / min),
//! throughput annotations, and a machine-readable JSON dump next to the
//! human-readable table. A `black_box` re-export prevents the optimizer from
//! deleting measured work.

use std::hint;
use std::time::{Duration, Instant};

/// Prevent the optimizer from const-folding away a benchmarked computation.
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// Statistics for a single benchmark. Times are f64 nanoseconds per
/// iteration (sub-nanosecond resolution matters for tiny hot-path ops).
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    /// Per-iteration wall time, nanoseconds.
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub iters: u64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<u64>,
}

impl Stats {
    /// Elements per second at the mean time, if `elements` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / (self.mean_ns * 1e-9))
    }

    /// Mean as a `Duration` (rounded to whole nanoseconds).
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns.max(0.0) as u64)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn fmt_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2} G/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2} M/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2} K/s", r / 1e3)
    } else {
        format!("{r:.2} /s")
    }
}

/// A benchmark group: configures measurement budget, collects results,
/// prints the table on drop.
pub struct Bench {
    group: String,
    warmup: Duration,
    measure: Duration,
    max_iters: u64,
    results: Vec<Stats>,
    elements: Option<u64>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // Respect a quick mode for CI: CLSTM_BENCH_FAST=1 shrinks budgets.
        let fast = std::env::var("CLSTM_BENCH_FAST").ok().as_deref() == Some("1");
        Self {
            group: group.to_string(),
            warmup: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(300)
            },
            measure: if fast {
                Duration::from_millis(100)
            } else {
                Duration::from_secs(1)
            },
            max_iters: 1_000_000_000,
            results: Vec::new(),
            elements: None,
        }
    }

    /// Set the measurement budget.
    pub fn measure_time(mut self, d: Duration) -> Self {
        self.measure = d;
        self
    }

    /// Annotate subsequent benches with a throughput element count.
    pub fn throughput(&mut self, elements: u64) -> &mut Self {
        self.elements = Some(elements);
        self
    }

    /// Run one benchmark: `f` is called repeatedly; its return value is
    /// black-boxed.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        // Warmup and per-iteration cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let est = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;

        // Choose a batch size so each sample is ≥ ~50µs (timer noise floor).
        let batch = ((50e-6 / est).ceil() as u64).clamp(1, self.max_iters);
        let target_samples = 60u64;
        let mut samples: Vec<f64> = Vec::with_capacity(target_samples as usize);
        let measure_start = Instant::now();
        let mut total_iters = 0u64;
        while measure_start.elapsed() < self.measure || samples.len() < 10 {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let dt = t0.elapsed();
            samples.push(dt.as_secs_f64() * 1e9 / batch as f64);
            total_iters += batch;
            if samples.len() >= 4 * target_samples as usize {
                break;
            }
        }
        samples.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let stats = Stats {
            name: format!("{}/{}", self.group, name),
            mean_ns: mean,
            median_ns: samples[n / 2],
            p95_ns: samples[(n * 95 / 100).min(n - 1)],
            min_ns: samples[0],
            iters: total_iters,
            elements: self.elements,
        };
        self.print_line(&stats);
        self.results.push(stats);
        self.results.last().unwrap()
    }

    fn print_line(&self, s: &Stats) {
        let tp = s
            .throughput()
            .map(|r| format!("  [{}]", fmt_rate(r)))
            .unwrap_or_default();
        println!(
            "{:<52} mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}  ({} iters){}",
            s.name,
            fmt_ns(s.mean_ns),
            fmt_ns(s.median_ns),
            fmt_ns(s.p95_ns),
            fmt_ns(s.min_ns),
            s.iters,
            tp
        );
    }

    /// All collected stats.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Dump results as JSON to `target/bench-results/<group>.json`.
    pub fn save_json(&self) {
        use crate::util::json::Json;
        let arr = Json::Arr(
            self.results
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("name", Json::str(s.name.clone())),
                        ("mean_ns", Json::num(s.mean_ns)),
                        ("median_ns", Json::num(s.median_ns)),
                        ("p95_ns", Json::num(s.p95_ns)),
                        ("min_ns", Json::num(s.min_ns)),
                        ("iters", Json::num(s.iters as f64)),
                    ])
                })
                .collect(),
        );
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.group.replace('/', "_")));
        let _ = std::fs::write(path, arr.to_pretty());
    }
}

impl Drop for Bench {
    fn drop(&mut self) {
        self.save_json();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_plausible() {
        std::env::set_var("CLSTM_BENCH_FAST", "1");
        let mut b = Bench::new("selftest").measure_time(Duration::from_millis(50));
        // Benchmark payload summing in mod-2^64 — exempt from the
        // crate-wide wrapping-op ban.
        #[allow(clippy::disallowed_methods)]
        let s = b
            .bench("sum1k", || (0..1000u64).fold(0u64, |a, x| a.wrapping_add(x)))
            .clone();
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.p95_ns);
    }

    #[test]
    fn throughput_annotation() {
        std::env::set_var("CLSTM_BENCH_FAST", "1");
        let mut b = Bench::new("selftest2").measure_time(Duration::from_millis(30));
        b.throughput(1000);
        let s = b.bench("tp", || black_box(3u64) * 2).clone();
        assert!(s.throughput().unwrap() > 0.0);
    }
}
