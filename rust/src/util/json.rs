//! A strict, dependency-free JSON parser and emitter.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), experiment
//! configuration files, golden-vector interchange with the Python layer, and
//! metric dumps from the serving coordinator. `serde`/`serde_json` are not
//! available offline; this implements RFC 8259 minus `\u` surrogate pairs
//! beyond the BMP (sufficient for our ASCII-only interchange, and rejected
//! loudly otherwise).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in sorted order (BTreeMap) so emission
/// is deterministic — important for golden files.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
    /// `get` + string.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }
    /// `get` + f64.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }
    /// `get` + usize.
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(Json::as_usize)
    }

    // --------------------------------------------------------- constructors
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Extract an array of f64 (errors mapped to None).
    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }
    /// Extract an array of f32.
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        Some(self.to_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    // --------------------------------------------------------------- parse
    /// Parse a complete JSON document (trailing whitespace allowed only).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let bytes = input.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------------------------------------------------------------- emit
    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialisation with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                let _ = write_num(out, *x);
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    item.write(out, indent, depth + 1);
                }
                if indent.is_some() && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

/// Write `contents` to `path` atomically: write a `.tmp` sibling, then
/// rename over the destination. A crash or failed bench run mid-write can
/// therefore never leave a truncated or half-serialised `BENCH_*.json` —
/// the destination either keeps its old contents or gets the complete new
/// ones.
pub fn write_atomic(path: &str, contents: &str) -> std::io::Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, contents)?;
    std::fs::rename(&tmp, path)
}

fn write_num(out: &mut String, x: f64) -> fmt::Result {
    use fmt::Write;
    if x.fract() == 0.0 && x.abs() < 1e15 {
        write!(out, "{}", x as i64)
    } else {
        // 17 significant digits round-trips f64 exactly.
        write!(out, "{:?}", x)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.i,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            if (0xD800..0xE000).contains(&code) {
                                return Err(self.err("surrogate pairs unsupported"));
                            }
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [1.5, -2e3, true, null], "c": {"nested": "x\ny"}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get_f64("a"), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(v.get("c").unwrap().get_str("nested"), Some("x\ny"));
        // Reparse the emission.
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn numbers_roundtrip_precisely() {
        for &x in &[0.0, 1.0, -1.5, 3.141592653589793, 1e-12, 2.2250738585072014e-308] {
            let s = Json::Num(x).to_string();
            let v = Json::parse(&s).unwrap();
            assert_eq!(v.as_f64().unwrap(), x, "{s}");
        }
    }

    #[test]
    fn f64_vec_helpers() {
        let v = Json::arr_f64(&[1.0, 2.0, 3.5]);
        assert_eq!(v.to_f64_vec().unwrap(), vec![1.0, 2.0, 3.5]);
        assert_eq!(v.to_f32_vec().unwrap(), vec![1.0f32, 2.0, 3.5]);
        assert!(Json::parse("[1, \"x\"]").unwrap().to_f64_vec().is_none());
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
        assert!(Json::parse(r#""\ud834""#).is_err());
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join(format!("clstm_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.json");
        let path = path.to_str().unwrap();
        write_atomic(path, "{\"a\":1}\n").unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "{\"a\":1}\n");
        // Overwrite: destination gets the complete new contents, and the
        // temp sibling is gone.
        write_atomic(path, "{\"a\":2}\n").unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "{\"a\":2}\n");
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
