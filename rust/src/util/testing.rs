//! Property-based testing harness (the offline stand-in for `proptest`).
//!
//! A property is checked over many generated cases; on failure the input is
//! greedily shrunk before reporting, so test failures show near-minimal
//! counterexamples. Used by the FFT, circulant, fixed-point, scheduler, and
//! PER test suites.
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla_extension rpath in this env)
//! use clstm::util::testing::{forall, Config, shrink_vec_f32, gen};
//! forall(
//!     Config::default().cases(64),
//!     |rng| gen::vec_f32(rng, 1..=32, -10.0, 10.0),
//!     shrink_vec_f32,
//!     |xs| {
//!         let s: f32 = xs.iter().sum();
//!         if s.is_finite() { Ok(()) } else { Err("sum not finite".into()) }
//!     },
//! );
//! ```

use crate::util::prng::Xoshiro256;
use std::fmt::Debug;

/// Test configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 128,
            seed: 0xC157,
            max_shrink_steps: 400,
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Check `prop` over `config.cases` inputs drawn by `generate`; on failure,
/// repeatedly apply `shrink` candidates that still fail, then panic with the
/// minimal case. `shrink` returns a list of strictly "smaller" candidates.
pub fn forall<T, G, S, P>(config: Config, generate: G, shrink: S, prop: P)
where
    T: Clone + Debug,
    G: Fn(&mut Xoshiro256) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Xoshiro256::seed_from_u64(config.seed);
    for case in 0..config.cases {
        let input = generate(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink.
            let mut best = input;
            let mut best_msg = first_msg;
            let mut steps = 0;
            'outer: while steps < config.max_shrink_steps {
                for cand in shrink(&best) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if steps >= config.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {:#x}):\n  input: {:?}\n  error: {}",
                config.seed, best, best_msg
            );
        }
    }
}

/// No shrinking.
pub fn no_shrink<T>(_: &T) -> Vec<T> {
    Vec::new()
}

/// Generators for common shapes.
pub mod gen {
    use super::*;
    use std::ops::RangeInclusive;

    /// Random length in `len`, values uniform in `[lo, hi)`.
    pub fn vec_f32(
        rng: &mut Xoshiro256,
        len: RangeInclusive<usize>,
        lo: f32,
        hi: f32,
    ) -> Vec<f32> {
        let n = *len.start() + rng.index(len.end() - len.start() + 1);
        (0..n)
            .map(|_| lo + (hi - lo) * rng.next_f32())
            .collect()
    }

    /// Random length in `len`, values uniform in `[lo, hi)`.
    pub fn vec_f64(
        rng: &mut Xoshiro256,
        len: RangeInclusive<usize>,
        lo: f64,
        hi: f64,
    ) -> Vec<f64> {
        let n = *len.start() + rng.index(len.end() - len.start() + 1);
        (0..n).map(|_| rng.uniform(lo, hi)).collect()
    }

    /// Power-of-two size in `[2^min_log2, 2^max_log2]`.
    pub fn pow2(rng: &mut Xoshiro256, min_log2: u32, max_log2: u32) -> usize {
        1usize << (min_log2 + rng.index((max_log2 - min_log2 + 1) as usize) as u32)
    }

    /// Integer in an inclusive range.
    pub fn usize_in(rng: &mut Xoshiro256, range: RangeInclusive<usize>) -> usize {
        range.start() + rng.index(range.end() - range.start() + 1)
    }
}

/// Shrinker for f32 vectors: tries halving the length (front/back halves)
/// and zeroing / halving individual elements.
pub fn shrink_vec_f32(xs: &Vec<f32>) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n > 1 {
        out.push(xs[..n / 2].to_vec());
        out.push(xs[n / 2..].to_vec());
    }
    if n >= 1 {
        for i in 0..n.min(4) {
            if xs[i] != 0.0 {
                let mut c = xs.clone();
                c[i] = 0.0;
                out.push(c);
            }
        }
    }
    out
}

/// Shrinker for f64 vectors.
pub fn shrink_vec_f64(xs: &Vec<f64>) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n > 1 {
        out.push(xs[..n / 2].to_vec());
        out.push(xs[n / 2..].to_vec());
    }
    for i in 0..n.min(4) {
        if xs[i] != 0.0 {
            let mut c = xs.clone();
            c[i] = 0.0;
            out.push(c);
        }
    }
    out
}

/// Assert two slices are elementwise close (absolute + relative tolerance),
/// reporting the worst offender.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    let mut worst = (0usize, 0.0f32);
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let err = (x - y).abs();
        let bound = atol + rtol * y.abs().max(x.abs());
        let excess = err - bound;
        if excess > worst.1 {
            worst = (i, excess);
        }
    }
    if worst.1 > 0.0 {
        let i = worst.0;
        panic!(
            "{what}: allclose failed at [{i}]: {} vs {} (excess {:.3e}, atol {atol}, rtol {rtol})",
            a[i], b[i], worst.1
        );
    }
}

/// f64 variant of [`assert_allclose`].
pub fn assert_allclose64(a: &[f64], b: &[f64], atol: f64, rtol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let err = (x - y).abs();
        assert!(
            err <= atol + rtol * y.abs().max(x.abs()),
            "{what}: allclose failed at [{i}]: {x} vs {y} (err {err:.3e})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            Config::default().cases(32),
            |rng| gen::vec_f32(rng, 0..=16, -1.0, 1.0),
            shrink_vec_f32,
            |xs| {
                if xs.iter().all(|x| x.abs() <= 1.0) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_input() {
        forall(
            Config::default().cases(64),
            |rng| gen::vec_f32(rng, 1..=64, -10.0, 10.0),
            shrink_vec_f32,
            |xs| {
                // Fails whenever the vector is non-empty → shrinks to len 1.
                if xs.is_empty() {
                    Ok(())
                } else {
                    Err(format!("len {}", xs.len()))
                }
            },
        );
    }

    #[test]
    fn pow2_generator_in_range() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..100 {
            let n = gen::pow2(&mut rng, 1, 5);
            assert!(n.is_power_of_two() && (2..=32).contains(&n));
        }
    }

    #[test]
    fn allclose_accepts_close() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0 - 1e-7], 1e-5, 1e-5, "t");
    }

    #[test]
    #[should_panic(expected = "allclose failed")]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[1.1], 1e-3, 1e-3, "t");
    }
}
