//! Infrastructure substrates.
//!
//! The offline environment ships no general-purpose crates (no `rand`,
//! `serde`, `clap`, `criterion`, `proptest`), so this module provides the
//! small, well-tested equivalents the rest of the crate builds on:
//!
//! - [`prng`] — SplitMix64 / xoshiro256** PRNGs with uniform & normal draws.
//! - [`json`] — a strict JSON parser/emitter for configs and manifests.
//! - [`cli`] — a declarative command-line argument parser.
//! - [`bench`] — a criterion-style measurement harness used by `cargo bench`.
//! - [`testing`] — property-based testing (generators + shrinking).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod testing;
