//! Declarative command-line parsing (the offline stand-in for `clap`).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options with
//! defaults, typed accessors, positional arguments, and auto-generated
//! `--help` text. Used by the `clstm` binary, the examples and the bench
//! harnesses.

use crate::num::fxp::Q;
use std::collections::BTreeMap;

/// Parse a `--q-format` style value: `auto` (⇒ `None`, let the range
/// analysis pick), a fractional-bit count (`12`), or an explicit 16-bit
/// split (`q3.12` / `Q3.12`, integer + fractional bits summing to 15 plus
/// the sign bit).
pub fn parse_q_format(s: &str) -> Result<Option<Q>, String> {
    let s = s.trim();
    if s.eq_ignore_ascii_case("auto") || s.is_empty() {
        return Ok(None);
    }
    let body = s.strip_prefix('q').or_else(|| s.strip_prefix('Q')).unwrap_or(s);
    let frac: u32 = if let Some((int_part, frac_part)) = body.split_once('.') {
        let i: u32 = int_part
            .parse()
            .map_err(|_| format!("bad Q-format {s:?} (expected: auto | <frac bits> | qI.F)"))?;
        let f: u32 = frac_part
            .parse()
            .map_err(|_| format!("bad Q-format {s:?} (expected: auto | <frac bits> | qI.F)"))?;
        if i > 15 || f > 15 || i + f != 15 {
            return Err(format!(
                "Q-format {s:?} needs integer + fractional bits = 15 (a \
                 16-bit word has 15 value bits plus the sign bit, e.g. q3.12)"
            ));
        }
        f
    } else {
        body.parse()
            .map_err(|_| format!("bad Q-format {s:?} (expected: auto | <frac bits> | qI.F)"))?
    };
    if frac > 15 {
        return Err(format!("Q-format {s:?}: at most 15 fractional bits"));
    }
    Ok(Some(Q::new(frac)))
}

/// Parse a `--replicas` style value: a fixed lane count (`4` ⇒ `(4, 4)`)
/// or an elastic range (`1..4` ⇒ `(1, 4)`, the engine scales lanes between
/// the two from occupancy). Both bounds must be ≥ 1 and `min ≤ max`.
pub fn parse_replicas(s: &str) -> Result<(usize, usize), String> {
    let s = s.trim();
    let bad = || format!("bad replica count {s:?} (expected: N | MIN..MAX, e.g. 2 or 1..4)");
    let (min, max) = if let Some((lo, hi)) = s.split_once("..") {
        let lo: usize = lo.trim().parse().map_err(|_| bad())?;
        let hi: usize = hi.trim().parse().map_err(|_| bad())?;
        (lo, hi)
    } else {
        let n: usize = s.parse().map_err(|_| bad())?;
        (n, n)
    };
    if min == 0 {
        return Err(format!("replica count {s:?}: at least one lane is required"));
    }
    if max < min {
        return Err(format!("replica range {s:?}: MIN must be ≤ MAX"));
    }
    Ok((min, max))
}

/// Parse a `--fault-inject` style value: `seed:rate[:once|persistent]` —
/// a PRNG seed, a per-executor fault probability in `[0, 1]`, and an
/// optional mode (`once` by default: each faulty executor fails exactly
/// once; `persistent`: it fails on every call). Returns
/// `(seed, rate, persistent)`; the caller maps the bool onto the runtime's
/// chaos mode so this module stays free of runtime dependencies.
pub fn parse_fault_inject(s: &str) -> Result<(u64, f64, bool), String> {
    let s = s.trim();
    let bad = || {
        format!(
            "bad fault spec {s:?} (expected: seed:rate[:once|persistent], e.g. 7:0.35 or 7:0.35:persistent)"
        )
    };
    let mut parts = s.split(':');
    let seed: u64 = parts.next().ok_or_else(bad)?.trim().parse().map_err(|_| bad())?;
    let rate: f64 = parts.next().ok_or_else(bad)?.trim().parse().map_err(|_| bad())?;
    let persistent = match parts.next().map(|m| m.trim().to_ascii_lowercase()) {
        None => false,
        Some(m) if m == "once" => false,
        Some(m) if m == "persistent" => true,
        Some(m) => return Err(format!("bad fault mode {m:?} (expected: once | persistent)")),
    };
    if parts.next().is_some() {
        return Err(bad());
    }
    if !rate.is_finite() || !(0.0..=1.0).contains(&rate) {
        return Err(format!("fault rate in {s:?} must be within [0, 1]"));
    }
    Ok((seed, rate, persistent))
}

/// Specification of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// A simple command-line parser: register options, then parse.
#[derive(Debug, Default)]
pub struct Cli {
    pub bin: String,
    pub about: String,
    opts: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Cli {
    pub fn new(bin: &str, about: &str) -> Self {
        Self {
            bin: bin.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Register a `--key value` option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Register a `--key value` option with no default (returns None if absent).
    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    /// Register a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nOPTIONS:\n", self.bin, self.about);
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else if let Some(d) = &o.default {
                format!("  --{} <value> (default: {})", o.name, d)
            } else {
                format!("  --{} <value>", o.name)
            };
            s.push_str(&format!("{head:<44} {}\n", o.help));
        }
        s
    }

    /// Parse an argument list (without the binary name). Returns Err with a
    /// message (or the help text for `--help`).
    pub fn parse(mut self, args: &[String]) -> Result<Self, String> {
        let known: BTreeMap<&str, bool> =
            self.opts.iter().map(|o| (o.name, o.is_flag)).collect();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.help());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                match known.get(key) {
                    Some(true) => {
                        if inline_val.is_some() {
                            return Err(format!("flag --{key} takes no value"));
                        }
                        self.flags.insert(key.to_string(), true);
                    }
                    Some(false) => {
                        let val = if let Some(v) = inline_val {
                            v
                        } else {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} needs a value"))?
                        };
                        self.values.insert(key.to_string(), val);
                    }
                    None => return Err(format!("unknown option --{key}\n\n{}", self.help())),
                }
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    /// Parse from `std::env::args`, skipping the binary name. On `--help` or
    /// error, prints and exits.
    pub fn parse_env(self) -> Self {
        let args: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&args) {
            Ok(c) => c,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.contains("OPTIONS:") { 0 } else { 2 });
            }
        }
    }

    // ------------------------------------------------------------ accessors
    /// Whether the option was explicitly passed (vs falling back to its
    /// default).
    pub fn is_set(&self, name: &str) -> bool {
        self.values.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        self.opts
            .iter()
            .find(|o| o.name == name)
            .and_then(|o| o.default.clone())
    }

    pub fn get_str(&self, name: &str) -> String {
        self.get(name)
            .unwrap_or_else(|| panic!("option --{name} not provided"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get_str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get_str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get_str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number"))
    }

    /// The option's value when it is present *and* non-empty — the accessor
    /// for optional output paths (`--trace`, `--metrics-json`), where an
    /// empty value means "off" just like an absent one.
    pub fn get_nonempty(&self, name: &str) -> Option<String> {
        self.get(name).filter(|v| !v.trim().is_empty())
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Typed accessor for a Q-format option (see [`parse_q_format`]):
    /// `Ok(None)` for `auto`, `Ok(Some(q))` for an explicit format.
    pub fn get_q_format(&self, name: &str) -> Result<Option<Q>, String> {
        parse_q_format(&self.get_str(name)).map_err(|e| format!("--{name}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let cli = Cli::new("t", "test")
            .opt("model", "google", "model name")
            .opt("steps", "100", "steps")
            .flag("verbose", "chatty")
            .parse(&argv("run --model small --verbose --steps=7 extra"))
            .unwrap();
        assert_eq!(cli.get_str("model"), "small");
        assert_eq!(cli.get_usize("steps"), 7);
        assert!(cli.get_flag("verbose"));
        assert_eq!(cli.positional(), &["run", "extra"]);
        assert!(cli.is_set("model") && cli.is_set("steps"));
    }

    #[test]
    fn defaults_apply() {
        let cli = Cli::new("t", "test")
            .opt("k", "8", "block size")
            .parse(&[])
            .unwrap();
        assert_eq!(cli.get_usize("k"), 8);
        assert!(!cli.get_flag("nope"));
        assert!(!cli.is_set("k"), "defaulted option is not explicitly set");
    }

    #[test]
    fn unknown_option_errors() {
        let e = Cli::new("t", "test").parse(&argv("--wat 3")).unwrap_err();
        assert!(e.contains("unknown option"));
    }

    #[test]
    fn help_lists_options() {
        let e = Cli::new("t", "about me")
            .opt("k", "8", "block size")
            .flag("fast", "go fast")
            .parse(&argv("--help"))
            .unwrap_err();
        assert!(e.contains("about me") && e.contains("--k") && e.contains("--fast"));
    }

    #[test]
    fn missing_value_errors() {
        let e = Cli::new("t", "t").opt("k", "8", "h").parse(&argv("--k")).unwrap_err();
        assert!(e.contains("needs a value"));
    }

    #[test]
    fn q_format_parses_auto_frac_and_split_forms() {
        assert_eq!(parse_q_format("auto").unwrap(), None);
        assert_eq!(parse_q_format("AUTO").unwrap(), None);
        assert_eq!(parse_q_format("12").unwrap(), Some(Q::new(12)));
        assert_eq!(parse_q_format("q3.12").unwrap(), Some(Q::new(12)));
        assert_eq!(parse_q_format("Q1.14").unwrap(), Some(Q::new(14)));
        // Bits must sum to 15 in the split form; frac capped at 15.
        assert!(parse_q_format("q4.12").unwrap_err().contains("15"));
        assert!(parse_q_format("16").is_err());
        assert!(parse_q_format("nope").is_err());
    }

    #[test]
    fn replicas_parses_fixed_and_range_forms() {
        assert_eq!(parse_replicas("4").unwrap(), (4, 4));
        assert_eq!(parse_replicas("1..4").unwrap(), (1, 4));
        assert_eq!(parse_replicas(" 2 .. 8 ").unwrap(), (2, 8));
        assert_eq!(parse_replicas("3..3").unwrap(), (3, 3));
        assert!(parse_replicas("0").unwrap_err().contains("at least one"));
        assert!(parse_replicas("0..4").unwrap_err().contains("at least one"));
        assert!(parse_replicas("4..2").unwrap_err().contains("MIN"));
        assert!(parse_replicas("nope").is_err());
        assert!(parse_replicas("1..").is_err());
        assert!(parse_replicas("..4").is_err());
    }

    #[test]
    fn fault_inject_parses_seed_rate_and_mode() {
        assert_eq!(parse_fault_inject("7:0.35").unwrap(), (7, 0.35, false));
        assert_eq!(parse_fault_inject("7:0.35:once").unwrap(), (7, 0.35, false));
        assert_eq!(parse_fault_inject("7:1:persistent").unwrap(), (7, 1.0, true));
        assert_eq!(parse_fault_inject(" 0:0 ").unwrap(), (0, 0.0, false));
        assert_eq!(
            parse_fault_inject("9:0.5:PERSISTENT").unwrap(),
            (9, 0.5, true),
            "mode is case-insensitive"
        );
        assert!(parse_fault_inject("7").is_err(), "rate is required");
        assert!(parse_fault_inject("7:1.5").unwrap_err().contains("[0, 1]"));
        assert!(parse_fault_inject("7:-0.1").unwrap_err().contains("[0, 1]"));
        assert!(parse_fault_inject("7:nan").unwrap_err().contains("[0, 1]"));
        assert!(parse_fault_inject("7:0.5:wat").unwrap_err().contains("once | persistent"));
        assert!(parse_fault_inject("7:0.5:once:extra").is_err());
        assert!(parse_fault_inject("nope:0.5").is_err());
    }

    #[test]
    fn nonempty_accessor_treats_blank_as_absent() {
        let cli = Cli::new("t", "t")
            .opt_req("trace", "h")
            .parse(&argv("--trace out.json"))
            .unwrap();
        assert_eq!(cli.get_nonempty("trace").as_deref(), Some("out.json"));
        let cli = Cli::new("t", "t").opt_req("trace", "h").parse(&[]).unwrap();
        assert_eq!(cli.get_nonempty("trace"), None);
        let cli = Cli::new("t", "t")
            .opt_req("trace", "h")
            .parse(&argv("--trace="))
            .unwrap();
        assert_eq!(cli.get_nonempty("trace"), None, "empty value means off");
    }

    #[test]
    fn q_format_accessor_reads_option() {
        let cli = Cli::new("t", "t")
            .opt("q-format", "auto", "h")
            .parse(&argv("--q-format q2.13"))
            .unwrap();
        assert_eq!(cli.get_q_format("q-format").unwrap(), Some(Q::new(13)));
        let cli = Cli::new("t", "t").opt("q-format", "auto", "h").parse(&[]).unwrap();
        assert_eq!(cli.get_q_format("q-format").unwrap(), None);
        let cli = Cli::new("t", "t")
            .opt("q-format", "auto", "h")
            .parse(&argv("--q-format wat"))
            .unwrap();
        assert!(cli.get_q_format("q-format").unwrap_err().contains("--q-format"));
    }
}
