//! Block-circulant LSTM weight bundles: initialisation, (de)serialisation,
//! and the golden-vector interchange with the Python (JAX) layer.
//!
//! Gate order is fixed as `i, f, g, o` (input, forget, cell-candidate,
//! output) everywhere — Rust engines, Python model, and AOT artifacts.
//!
//! The on-disk format is a small JSON header (spec + array manifest)
//! followed by raw little-endian `f32` payloads, so the 8M-parameter Google
//! model loads in milliseconds and the exact same bytes can be produced by
//! `python/compile/train.py`.

use super::config::LstmSpec;
use crate::circulant::BlockCirculant;
use crate::util::json::Json;
use crate::util::prng::Xoshiro256;
use anyhow::{bail, Context};
use std::io::{Read, Write};
use std::path::Path;

/// Gate indices.
pub const GATE_I: usize = 0;
pub const GATE_F: usize = 1;
pub const GATE_G: usize = 2;
pub const GATE_O: usize = 3;

/// Weights of one direction of one layer.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// Fused gate matrices `W_{*(xr)}` over `[x_t, y_{t-1}]` (padded), in
    /// gate order i, f, g, o. Shape: `hidden_pad × fused_in`.
    pub gates: [BlockCirculant; 4],
    /// Gate biases (length `hidden`).
    pub bias: [Vec<f32>; 4],
    /// Peephole vectors `w_ic, w_fc, w_oc` (diagonal matrices ⇒ vectors).
    pub peephole: Option<[Vec<f32>; 3]>,
    /// Projection `W_ym` (`proj_pad × hidden_pad`), if the spec has one.
    pub proj: Option<BlockCirculant>,
}

/// All weights of a model, plus the small dense classifier head used by the
/// PER evaluation.
#[derive(Debug, Clone)]
pub struct LstmWeights {
    pub spec: LstmSpec,
    /// `layers[l][d]` — layer `l`, direction `d`.
    pub layers: Vec<Vec<LayerWeights>>,
    /// Dense classifier `num_classes × final_out` (row-major) + bias.
    pub classifier: Option<(Vec<f32>, Vec<f32>)>,
}

impl LstmWeights {
    /// Random initialisation (Glorot for matrices, +1.0 forget-gate bias —
    /// the standard recipe; the Python trainer uses the same).
    pub fn random(spec: &LstmSpec, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut layers = Vec::new();
        for l in 0..spec.layers {
            let mut dirs = Vec::new();
            for _d in 0..spec.directions() {
                dirs.push(Self::random_layer(spec, l, &mut rng));
            }
            layers.push(dirs);
        }
        let classifier = if spec.num_classes > 0 {
            let final_out = spec.out_dim() * spec.directions();
            let std = (2.0 / (final_out + spec.num_classes) as f64).sqrt();
            let w: Vec<f32> = (0..spec.num_classes * final_out)
                .map(|_| rng.normal_with(0.0, std) as f32)
                .collect();
            let b = vec![0.0f32; spec.num_classes];
            Some((w, b))
        } else {
            None
        };
        Self {
            spec: spec.clone(),
            layers,
            classifier,
        }
    }

    fn random_layer(spec: &LstmSpec, l: usize, rng: &mut Xoshiro256) -> LayerWeights {
        let h = spec.pad(spec.hidden_dim);
        let fused = spec.fused_in_dim(l);
        let gates = [
            BlockCirculant::random_init(h, fused, spec.k, rng),
            BlockCirculant::random_init(h, fused, spec.k, rng),
            BlockCirculant::random_init(h, fused, spec.k, rng),
            BlockCirculant::random_init(h, fused, spec.k, rng),
        ];
        let mut bias = [
            vec![0.0f32; spec.hidden_dim],
            vec![0.0f32; spec.hidden_dim],
            vec![0.0f32; spec.hidden_dim],
            vec![0.0f32; spec.hidden_dim],
        ];
        // Forget-gate bias +1 stabilises early training and is what the
        // Python trainer exports.
        for b in bias[GATE_F].iter_mut() {
            *b = 1.0;
        }
        let peephole = if spec.peephole {
            let mut mk = || {
                (0..spec.hidden_dim)
                    .map(|_| rng.normal_with(0.0, 0.1) as f32)
                    .collect::<Vec<f32>>()
            };
            Some([mk(), mk(), mk()])
        } else {
            None
        };
        let proj = spec
            .proj_dim
            .map(|p| BlockCirculant::random_init(spec.pad(p), h, spec.k, rng));
        LayerWeights {
            gates,
            bias,
            peephole,
            proj,
        }
    }

    // ------------------------------------------------------------- save/load

    /// Serialise to the `CLSTMW1` container (JSON header + raw f32).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut arrays: Vec<(String, &[f32])> = Vec::new();
        for (l, dirs) in self.layers.iter().enumerate() {
            for (d, lw) in dirs.iter().enumerate() {
                for (g, name) in ["i", "f", "g", "o"].iter().enumerate() {
                    arrays.push((format!("l{l}.d{d}.w_{name}"), &lw.gates[g].w));
                    arrays.push((format!("l{l}.d{d}.b_{name}"), &lw.bias[g]));
                }
                if let Some(p) = &lw.peephole {
                    arrays.push((format!("l{l}.d{d}.p_ic"), &p[0]));
                    arrays.push((format!("l{l}.d{d}.p_fc"), &p[1]));
                    arrays.push((format!("l{l}.d{d}.p_oc"), &p[2]));
                }
                if let Some(pr) = &lw.proj {
                    arrays.push((format!("l{l}.d{d}.w_proj"), &pr.w));
                }
            }
        }
        if let Some((w, b)) = &self.classifier {
            arrays.push(("cls.w".into(), w));
            arrays.push(("cls.b".into(), b));
        }
        let manifest = Json::Arr(
            arrays
                .iter()
                .map(|(n, a)| {
                    Json::obj(vec![
                        ("name", Json::str(n.clone())),
                        ("len", Json::num(a.len() as f64)),
                    ])
                })
                .collect(),
        );
        let header = Json::obj(vec![
            ("format", Json::str("CLSTMW1")),
            ("model", Json::str(self.spec.kind.as_str())),
            ("k", Json::num(self.spec.k as f64)),
            ("input_dim", Json::num(self.spec.input_dim as f64)),
            ("hidden_dim", Json::num(self.spec.hidden_dim as f64)),
            (
                "proj_dim",
                self.spec
                    .proj_dim
                    .map(|p| Json::num(p as f64))
                    .unwrap_or(Json::Null),
            ),
            ("peephole", Json::Bool(self.spec.peephole)),
            ("layers", Json::num(self.spec.layers as f64)),
            ("bidirectional", Json::Bool(self.spec.bidirectional)),
            ("num_classes", Json::num(self.spec.num_classes as f64)),
            ("arrays", manifest),
        ])
        .to_string();

        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(b"CLSTMW1\n")?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, a) in &arrays {
            let bytes: Vec<u8> = a.iter().flat_map(|v| v.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    /// Load a `CLSTMW1` container. The spec is reconstructed from the
    /// header; array shapes are re-derived from it and validated against
    /// the manifest.
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"CLSTMW1\n" {
            bail!("{}: not a CLSTMW1 weight file", path.display());
        }
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb)?;
        let hlen = u64::from_le_bytes(lenb) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow::anyhow!("weight header: {e}"))?;

        let kind = match header.get_str("model") {
            Some("google") => super::config::ModelKind::Google,
            _ => super::config::ModelKind::Small,
        };
        let spec = LstmSpec {
            kind,
            input_dim: header.get_usize("input_dim").context("input_dim")?,
            hidden_dim: header.get_usize("hidden_dim").context("hidden_dim")?,
            proj_dim: header.get("proj_dim").and_then(Json::as_usize),
            peephole: header.get("peephole").and_then(Json::as_bool).unwrap_or(false),
            layers: header.get_usize("layers").context("layers")?,
            bidirectional: header
                .get("bidirectional")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            k: header.get_usize("k").context("k")?,
            num_classes: header.get_usize("num_classes").unwrap_or(0),
        };

        let manifest = header
            .get("arrays")
            .and_then(Json::as_arr)
            .context("arrays manifest")?;
        let mut order: Vec<(String, usize)> = Vec::new();
        for a in manifest {
            order.push((
                a.get_str("name").context("array name")?.to_string(),
                a.get_usize("len").context("array len")?,
            ));
        }
        let mut data = std::collections::BTreeMap::new();
        for (name, len) in &order {
            let mut buf = vec![0u8; len * 4];
            f.read_exact(&mut buf)
                .with_context(|| format!("reading array {name}"))?;
            let vals: Vec<f32> = buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            data.insert(name.clone(), vals);
        }

        let mut take = |name: String| -> anyhow::Result<Vec<f32>> {
            data.remove(&name).with_context(|| format!("missing array {name}"))
        };

        let mut layers = Vec::new();
        for l in 0..spec.layers {
            let mut dirs = Vec::new();
            for d in 0..spec.directions() {
                let h = spec.pad(spec.hidden_dim);
                let fused = spec.fused_in_dim(l);
                let mut gates = Vec::new();
                let mut bias = Vec::new();
                for name in ["i", "f", "g", "o"] {
                    gates.push(BlockCirculant::from_vectors(
                        h,
                        fused,
                        spec.k,
                        take(format!("l{l}.d{d}.w_{name}"))?,
                    ));
                    bias.push(take(format!("l{l}.d{d}.b_{name}"))?);
                }
                let gates: [BlockCirculant; 4] =
                    gates.try_into().map_err(|_| anyhow::anyhow!("gate count"))?;
                let bias: [Vec<f32>; 4] =
                    bias.try_into().map_err(|_| anyhow::anyhow!("bias count"))?;
                let peephole = if spec.peephole {
                    Some([
                        take(format!("l{l}.d{d}.p_ic"))?,
                        take(format!("l{l}.d{d}.p_fc"))?,
                        take(format!("l{l}.d{d}.p_oc"))?,
                    ])
                } else {
                    None
                };
                let proj = match spec.proj_dim {
                    Some(p) => Some(BlockCirculant::from_vectors(
                        spec.pad(p),
                        h,
                        spec.k,
                        take(format!("l{l}.d{d}.w_proj"))?,
                    )),
                    None => None,
                };
                dirs.push(LayerWeights {
                    gates,
                    bias,
                    peephole,
                    proj,
                });
            }
            layers.push(dirs);
        }
        let classifier = if spec.num_classes > 0 {
            Some((take("cls.w".into())?, take("cls.b".into())?))
        } else {
            None
        };
        Ok(Self {
            spec,
            layers,
            classifier,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_save_load_tiny() {
        let spec = LstmSpec::tiny(4);
        let w = LstmWeights::random(&spec, 99);
        let dir = std::env::temp_dir().join("clstm_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.clstmw");
        w.save(&path).unwrap();
        let r = LstmWeights::load(&path).unwrap();
        assert_eq!(r.spec, spec);
        assert_eq!(r.layers.len(), w.layers.len());
        assert_eq!(r.layers[0][0].gates[0].w, w.layers[0][0].gates[0].w);
        assert_eq!(r.layers[0][0].bias[1], w.layers[0][0].bias[1]);
        assert_eq!(
            r.layers[0][0].peephole.as_ref().unwrap()[2],
            w.layers[0][0].peephole.as_ref().unwrap()[2]
        );
        assert_eq!(
            r.classifier.as_ref().unwrap().0,
            w.classifier.as_ref().unwrap().0
        );
    }

    #[test]
    fn roundtrip_bidirectional() {
        let spec = LstmSpec::small(8);
        // Shrink for test speed.
        let spec = LstmSpec {
            hidden_dim: 64,
            layers: 2,
            ..spec
        };
        let w = LstmWeights::random(&spec, 7);
        assert_eq!(w.layers[0].len(), 2, "two directions");
        let dir = std::env::temp_dir().join("clstm_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bidir.clstmw");
        w.save(&path).unwrap();
        let r = LstmWeights::load(&path).unwrap();
        assert_eq!(r.layers[1][1].gates[3].w, w.layers[1][1].gates[3].w);
    }

    #[test]
    fn forget_bias_is_one() {
        let w = LstmWeights::random(&LstmSpec::tiny(2), 1);
        assert!(w.layers[0][0].bias[GATE_F].iter().all(|&b| b == 1.0));
        assert!(w.layers[0][0].bias[GATE_I].iter().all(|&b| b == 0.0));
    }

    #[test]
    fn load_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("clstm_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.clstmw");
        std::fs::write(&path, b"NOTVALID........").unwrap();
        assert!(LstmWeights::load(&path).is_err());
    }
}
