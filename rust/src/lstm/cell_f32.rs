//! Float LSTM cell engine over the optimized circulant convolution (Eq 1,
//! Eq 6) — the accuracy reference for the fixed-point engine and the
//! numerical twin of the JAX layer-2 model.
//!
//! Note on Eq 1c: the paper prints `g_t = σ(...)`; the architecture it
//! cites (Google LSTM, Sak et al. [25]) uses tanh for the cell candidate,
//! and so do we (configurable via [`CellF32::cell_activation`]).

use super::activations::{sigmoid, tanh, ActivationMode, PwlTable};
use super::config::LstmSpec;
use super::weights::{LayerWeights, GATE_F, GATE_G, GATE_I, GATE_O};
use crate::circulant::conv::{matvec_eq6_into, Eq6Scratch};
use crate::circulant::spectral::SpectralWeights;
use crate::num::fxp::Q;

/// One direction of one layer, ready to run: spectral weights precomputed
/// (the "BRAM-resident `F(w)`" of §4.1).
pub struct CellF32 {
    pub spec: LstmSpec,
    /// Layer index (for dimension bookkeeping).
    pub layer: usize,
    gates_spec: [SpectralWeights; 4],
    bias: [Vec<f32>; 4],
    peephole: Option<[Vec<f32>; 3]>,
    proj_spec: Option<SpectralWeights>,
    mode: ActivationMode,
    scratch: std::cell::RefCell<Eq6Scratch>,
    pwl_sigmoid: PwlTable,
    pwl_tanh: PwlTable,
    /// Padded dims.
    in_pad: usize,
    out_pad: usize,
    hidden_pad: usize,
}

/// Recurrent state of one cell: previous output `y` (padded) and cell
/// state `c`.
#[derive(Debug, Clone)]
pub struct CellState {
    pub y: Vec<f32>,
    pub c: Vec<f32>,
}

impl CellF32 {
    /// Build from layer weights, precomputing all spectra.
    pub fn new(spec: &LstmSpec, layer: usize, w: &LayerWeights, mode: ActivationMode) -> Self {
        let q = Q::new(12);
        Self {
            spec: spec.clone(),
            layer,
            gates_spec: [
                SpectralWeights::precompute(&w.gates[0]),
                SpectralWeights::precompute(&w.gates[1]),
                SpectralWeights::precompute(&w.gates[2]),
                SpectralWeights::precompute(&w.gates[3]),
            ],
            bias: w.bias.clone(),
            peephole: w.peephole.clone(),
            proj_spec: w.proj.as_ref().map(SpectralWeights::precompute),
            mode,
            scratch: std::cell::RefCell::new(Eq6Scratch::default()),
            pwl_sigmoid: PwlTable::sigmoid(q),
            pwl_tanh: PwlTable::tanh(q),
            in_pad: spec.pad(spec.layer_input_dim(layer)),
            out_pad: spec.pad(spec.out_dim()),
            hidden_pad: spec.pad(spec.hidden_dim),
        }
    }

    /// Fresh zero state.
    pub fn zero_state(&self) -> CellState {
        CellState {
            y: vec![0.0; self.out_pad],
            c: vec![0.0; self.spec.hidden_dim],
        }
    }

    #[inline]
    fn act_sigma(&self, x: f32) -> f32 {
        match self.mode {
            ActivationMode::Exact => sigmoid(x),
            ActivationMode::Pwl => self.pwl_sigmoid.eval(x),
        }
    }

    #[inline]
    fn act_h(&self, x: f32) -> f32 {
        match self.mode {
            ActivationMode::Exact => tanh(x),
            ActivationMode::Pwl => self.pwl_tanh.eval(x),
        }
    }

    /// One time step (Eq 1a–1g). `x` is the (unpadded) layer input;
    /// `state` is updated in place; returns the (padded) output `y_t`
    /// slice — callers read `..spec.out_dim()`.
    pub fn step(&self, x: &[f32], state: &mut CellState) -> Vec<f32> {
        let h = self.spec.hidden_dim;
        assert!(x.len() <= self.in_pad, "input longer than padded dim");
        // Fused operand [x_t (padded); y_{t-1} (padded)].
        let mut fused = vec![0.0f32; self.in_pad + self.out_pad];
        fused[..x.len()].copy_from_slice(x);
        fused[self.in_pad..self.in_pad + state.y.len()].copy_from_slice(&state.y);

        // Nine (here: four fused + projection) circulant mat-vecs,
        // allocation-free through the shared scratch.
        let mut scratch = self.scratch.borrow_mut();
        let mut a_i = vec![0.0f32; self.hidden_pad];
        let mut a_f = vec![0.0f32; self.hidden_pad];
        let mut a_g = vec![0.0f32; self.hidden_pad];
        let mut a_o = vec![0.0f32; self.hidden_pad];
        matvec_eq6_into(&self.gates_spec[GATE_I], &fused, &mut a_i, &mut scratch);
        matvec_eq6_into(&self.gates_spec[GATE_F], &fused, &mut a_f, &mut scratch);
        matvec_eq6_into(&self.gates_spec[GATE_G], &fused, &mut a_g, &mut scratch);
        matvec_eq6_into(&self.gates_spec[GATE_O], &fused, &mut a_o, &mut scratch);

        let zero3;
        let peep = match &self.peephole {
            Some(p) => p,
            None => {
                zero3 = [vec![0.0f32; h], vec![0.0f32; h], vec![0.0f32; h]];
                &zero3
            }
        };

        let mut m = vec![0.0f32; self.hidden_pad];
        for n in 0..h {
            // Eq 1a, 1b: peepholes read c_{t-1}.
            let i = self.act_sigma(a_i[n] + peep[0][n] * state.c[n] + self.bias[GATE_I][n]);
            let f = self.act_sigma(a_f[n] + peep[1][n] * state.c[n] + self.bias[GATE_F][n]);
            // Eq 1c (tanh candidate — see module docs).
            let g = self.act_h(a_g[n] + self.bias[GATE_G][n]);
            // Eq 1d.
            let c = f * state.c[n] + g * i;
            // Eq 1e: output peephole reads c_t.
            let o = self.act_sigma(a_o[n] + peep[2][n] * c + self.bias[GATE_O][n]);
            // Eq 1f.
            m[n] = o * self.act_h(c);
            state.c[n] = c;
        }

        // Eq 1g: projection (or identity).
        let y = match &self.proj_spec {
            Some(p) => {
                let mut y = vec![0.0f32; p.p * p.k];
                matvec_eq6_into(p, &m, &mut y, &mut scratch);
                y
            }
            None => m,
        };
        state.y.copy_from_slice(&y[..self.out_pad.min(y.len())]);
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::weights::LstmWeights;
    use crate::util::prng::Xoshiro256;
    use crate::util::testing::assert_allclose;

    fn tiny_cell(k: usize, mode: ActivationMode) -> (LstmSpec, CellF32) {
        let spec = LstmSpec::tiny(k);
        let w = LstmWeights::random(&spec, 5);
        let cell = CellF32::new(&spec, 0, &w.layers[0][0], mode);
        (spec, cell)
    }

    #[test]
    fn outputs_bounded_and_finite() {
        let (spec, cell) = tiny_cell(4, ActivationMode::Exact);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut st = cell.zero_state();
        for _ in 0..50 {
            let x: Vec<f32> = (0..spec.input_dim)
                .map(|_| rng.uniform(-1.0, 1.0) as f32)
                .collect();
            let y = cell.step(&x, &mut st);
            assert!(y.iter().all(|v| v.is_finite()));
            // Cell state is bounded by the gate structure: |c| grows at
            // most by 1 per step (f ≤ 1, |g·i| ≤ 1).
            assert!(st.c.iter().all(|v| v.abs() <= 51.0));
        }
    }

    #[test]
    fn zero_input_zero_state_gives_projection_of_constants() {
        // With x = 0, y0 = 0, c0 = 0: i = σ(b_i), f = σ(1), g = tanh(0) = 0
        // ⇒ c1 = 0 ⇒ m = 0 ⇒ y = 0.
        let (spec, cell) = tiny_cell(2, ActivationMode::Exact);
        let mut st = cell.zero_state();
        let y = cell.step(&vec![0.0; spec.input_dim], &mut st);
        assert_allclose(&y, &vec![0.0; y.len()], 1e-5, 0.0, "zero step");
        assert_allclose(&st.c, &vec![0.0; st.c.len()], 1e-5, 0.0, "zero cell");
    }

    #[test]
    fn k1_matches_k1_dense_semantics() {
        // k=1 blocks are scalars: circulant conv is exactly a dense matvec,
        // so two different code paths must agree (dense built via to_dense).
        let spec = LstmSpec::tiny(1);
        let w = LstmWeights::random(&spec, 11);
        let cell = CellF32::new(&spec, 0, &w.layers[0][0], ActivationMode::Exact);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let x: Vec<f32> = (0..spec.input_dim)
            .map(|_| rng.uniform(-1.0, 1.0) as f32)
            .collect();
        // Manual dense step.
        let lw = &w.layers[0][0];
        let fused_dim = spec.fused_in_dim(0);
        let mut fused = vec![0.0f32; fused_dim];
        fused[..x.len()].copy_from_slice(&x);
        let dense_mv = |m: &crate::circulant::BlockCirculant, v: &[f32]| -> Vec<f32> {
            let d = m.to_dense();
            (0..m.rows)
                .map(|r| (0..m.cols).map(|c| d[r * m.cols + c] * v[c]).sum())
                .collect()
        };
        let a_i = dense_mv(&lw.gates[0], &fused);
        let a_f = dense_mv(&lw.gates[1], &fused);
        let a_g = dense_mv(&lw.gates[2], &fused);
        let a_o = dense_mv(&lw.gates[3], &fused);
        let p = lw.peephole.as_ref().unwrap();
        let h = spec.hidden_dim;
        let mut m_vec = vec![0.0f32; h];
        let mut c_vec = vec![0.0f32; h];
        for n in 0..h {
            let i = sigmoid(a_i[n] + lw.bias[0][n]);
            let f = sigmoid(a_f[n] + lw.bias[1][n]);
            let g = tanh(a_g[n] + lw.bias[2][n]);
            let c = g * i;
            let o = sigmoid(a_o[n] + p[2][n] * c + lw.bias[3][n]);
            m_vec[n] = o * tanh(c);
            c_vec[n] = c;
            let _ = f;
        }
        let y_expect = dense_mv(lw.proj.as_ref().unwrap(), &m_vec);

        let mut st = cell.zero_state();
        let y = cell.step(&x, &mut st);
        assert_allclose(&y, &y_expect, 2e-4, 2e-3, "k=1 engine vs dense math");
        assert_allclose(&st.c, &c_vec, 2e-4, 2e-3, "cell state");
    }

    #[test]
    fn pwl_engine_close_to_exact_engine() {
        let (spec, exact) = tiny_cell(4, ActivationMode::Exact);
        let w = LstmWeights::random(&spec, 5); // same seed as tiny_cell
        let pwl = CellF32::new(&spec, 0, &w.layers[0][0], ActivationMode::Pwl);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut st_e = exact.zero_state();
        let mut st_p = pwl.zero_state();
        let mut max_dev = 0.0f32;
        for _ in 0..20 {
            let x: Vec<f32> = (0..spec.input_dim)
                .map(|_| rng.uniform(-1.0, 1.0) as f32)
                .collect();
            let ye = exact.step(&x, &mut st_e);
            let yp = pwl.step(&x, &mut st_p);
            for (a, b) in ye.iter().zip(&yp) {
                max_dev = max_dev.max((a - b).abs());
            }
        }
        // PWL error ≤1% per activation; through gates and 20 steps the
        // deviation stays small but non-zero.
        assert!(max_dev > 0.0, "PWL should differ from exact");
        assert!(max_dev < 0.15, "PWL divergence too large: {max_dev}");
    }

    #[test]
    fn state_carries_information() {
        let (spec, cell) = tiny_cell(4, ActivationMode::Exact);
        let x1: Vec<f32> = (0..spec.input_dim).map(|i| (i as f32 * 0.1).sin()).collect();
        let x2: Vec<f32> = (0..spec.input_dim).map(|i| (i as f32 * 0.3).cos()).collect();
        // Same second input, different first input ⇒ different outputs.
        let mut s_a = cell.zero_state();
        cell.step(&x1, &mut s_a);
        let ya = cell.step(&x2, &mut s_a);
        let mut s_b = cell.zero_state();
        cell.step(&x2, &mut s_b);
        let yb = cell.step(&x2, &mut s_b);
        let diff: f32 = ya.iter().zip(&yb).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-4, "recurrence must carry state (diff {diff})");
    }
}
