//! LSTM architecture specifications and parameter accounting (§2, §3.3, §6).
//!
//! Two concrete models are evaluated in the paper, both reproduced here:
//!
//! - **Google LSTM** [25] (the ESE baseline architecture): 153-dim input
//!   (51 mel filterbank coefficients + energy, with Δ and ΔΔ), 1024 cells,
//!   peephole connections, 512-dim recurrent projection, two stacked
//!   layers. At block size 1 this is the 8.01 M-parameter model of Table 1.
//! - **Small LSTM** [20] (§6.1): 39-dim input (12 filterbank coefficients +
//!   energy, with Δ and ΔΔ), 512 cells, no peephole, no projection,
//!   bidirectional, two stacked layers.
//!
//! Dimensions that are not multiples of the block size `k` are zero-padded
//! up to the next multiple (the input feature dim 153 → 160 for k ∈ {8,16});
//! padding contributes parameters exactly as an FPGA BRAM layout would.

use crate::circulant::compress::CompressionStats;

/// Which of the paper's two models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Google,
    Small,
}

impl ModelKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::Google => "google",
            ModelKind::Small => "small",
        }
    }
}

/// Architecture specification of a (possibly stacked, possibly
/// bidirectional) LSTM with optional peepholes and projection, compressed
/// with block-circulant matrices of block size `k`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LstmSpec {
    pub kind: ModelKind,
    /// Raw input feature dimension (pre-padding).
    pub input_dim: usize,
    /// Gate/cell dimension.
    pub hidden_dim: usize,
    /// Projection (output) dimension; `None` ⇒ output = cell output `m_t`.
    pub proj_dim: Option<usize>,
    /// Peephole connections `W_ic, W_fc, W_oc` (diagonal ⇒ element-wise).
    pub peephole: bool,
    /// Stacked layers.
    pub layers: usize,
    /// Bidirectional (outputs of the two directions are concatenated).
    pub bidirectional: bool,
    /// Circulant block size (1 = uncompressed dense).
    pub k: usize,
    /// Output classes of the final affine layer (phones incl. blank); used
    /// by the PER evaluation. 0 ⇒ no classifier head.
    pub num_classes: usize,
}

impl LstmSpec {
    /// The Google LSTM [25] at block size `k` (Table 1 / Table 3 rows).
    pub fn google(k: usize) -> Self {
        Self {
            kind: ModelKind::Google,
            input_dim: 153,
            hidden_dim: 1024,
            proj_dim: Some(512),
            peephole: true,
            layers: 2,
            bidirectional: false,
            k,
            num_classes: 39,
        }
    }

    /// The Small LSTM [20] at block size `k` (§6.1, §6.3).
    pub fn small(k: usize) -> Self {
        Self {
            kind: ModelKind::Small,
            input_dim: 39,
            hidden_dim: 512,
            proj_dim: None,
            peephole: false,
            layers: 2,
            bidirectional: true,
            k,
            num_classes: 39,
        }
    }

    /// A tiny configuration for tests and the quickstart example.
    pub fn tiny(k: usize) -> Self {
        Self {
            kind: ModelKind::Small,
            input_dim: 16,
            hidden_dim: 32,
            proj_dim: Some(16),
            peephole: true,
            layers: 1,
            bidirectional: false,
            k,
            num_classes: 8,
        }
    }

    /// Round `dim` up to a multiple of the block size.
    pub fn pad(&self, dim: usize) -> usize {
        dim.div_ceil(self.k) * self.k
    }

    /// Output dimension of one direction of one layer.
    pub fn out_dim(&self) -> usize {
        self.proj_dim.unwrap_or(self.hidden_dim)
    }

    /// Input dimension seen by layer `l` (0-based): raw features for layer
    /// 0, previous layer's (possibly bidirectional-concatenated) output
    /// otherwise.
    pub fn layer_input_dim(&self, l: usize) -> usize {
        if l == 0 {
            self.input_dim
        } else {
            self.out_dim() * if self.bidirectional { 2 } else { 1 }
        }
    }

    /// Dimension of the fused mat-vec operand `[x_t, y_{t-1}]` for layer
    /// `l`, after padding both halves to block-size multiples.
    pub fn fused_in_dim(&self, l: usize) -> usize {
        self.pad(self.layer_input_dim(l)) + self.pad(self.out_dim())
    }

    /// Directions (1 or 2).
    pub fn directions(&self) -> usize {
        if self.bidirectional {
            2
        } else {
            1
        }
    }

    /// Compression stats of all *matrix* weights (the quantity Tables 1
    /// and 3 track; peepholes/biases are vectors and excluded from matrix
    /// compression ratios, matching the paper's "matrix compression ratio").
    pub fn matrix_stats(&self) -> CompressionStats {
        let mut per = Vec::new();
        for l in 0..self.layers {
            let fused = self.fused_in_dim(l);
            let h = self.pad(self.hidden_dim);
            // Four gates: i, f, c, o.
            for _ in 0..4 {
                per.push(CompressionStats::for_matrix(h, fused, self.k));
            }
            if let Some(p) = self.proj_dim {
                per.push(CompressionStats::for_matrix(self.pad(p), h, self.k));
            }
        }
        let mut combined = CompressionStats::combine(&per);
        // Bidirectional doubles every matrix.
        combined.dense_params *= self.directions();
        combined.circulant_params *= self.directions();
        combined
    }

    /// Total stored parameters including peepholes and biases — the
    /// Table 1 "#Model Parameters" column.
    pub fn total_params(&self) -> usize {
        let m = self.matrix_stats().circulant_params;
        let mut vecs = 0usize;
        for _ in 0..self.layers {
            vecs += 4 * self.hidden_dim; // biases
            if self.peephole {
                vecs += 3 * self.hidden_dim;
            }
        }
        m + vecs * self.directions()
    }

    /// Parameters of the single first layer — the Table 3 "Weight Matrix
    /// Size (#Parameters of LSTM)" row counts one layer of the model.
    pub fn layer1_matrix_params(&self) -> usize {
        let fused = self.fused_in_dim(0);
        let h = self.pad(self.hidden_dim);
        let mut per = vec![CompressionStats::for_matrix(h, fused, self.k); 4];
        if let Some(p) = self.proj_dim {
            per.push(CompressionStats::for_matrix(self.pad(p), h, self.k));
        }
        CompressionStats::combine(&per).circulant_params * self.directions()
    }

    /// The Table 1 "Computational Complexity" column, normalised to the
    /// dense model. The paper reports the asymptotic operator-count ratio
    /// `O(k log k) / O(k²) = log2(k)/k` (its rows: k=2 → 0.50, k=4 → 0.50,
    /// k=8 → 0.39 ≈ 0.375, k=16 → 0.27 ≈ 0.25 — the small excess being
    /// element-wise overhead). We reproduce exactly that metric;
    /// [`Self::flops_vs_dense`] gives the finer real-flop estimate used by
    /// the performance model.
    pub fn complexity_vs_dense(&self) -> f64 {
        if self.k == 1 {
            1.0
        } else {
            (self.k as f64).log2() / self.k as f64
        }
    }

    /// Measured-flop ratio of the Eq 6 circulant inference versus dense
    /// (`k = 1`), summed over all matrices of the model.
    ///
    /// Dense mat-vec: `2·m·n` flops. FFT-based circulant conv (Eq 6 with
    /// per-`j` shared DFTs): per matrix `(q + p)·(k/2)·log2(k)·5` flops for
    /// the transforms (radix-2 real FFT butterflies ≈ 5 real flops each)
    /// plus `p·q·k·4` for the packed ⊙-accumulate. Element-wise operators
    /// are identical across block sizes and excluded, as in the paper.
    pub fn flops_vs_dense(&self) -> f64 {
        let mut dense_flops = 0.0f64;
        let mut circ_flops = 0.0f64;
        for l in 0..self.layers {
            let mut dims = vec![(self.pad(self.hidden_dim), self.fused_in_dim(l)); 4];
            if let Some(p) = self.proj_dim {
                dims.push((self.pad(p), self.pad(self.hidden_dim)));
            }
            for (m, n) in dims {
                dense_flops += 2.0 * (m * n) as f64;
                if self.k == 1 {
                    circ_flops += 2.0 * (m * n) as f64;
                } else {
                    let p = m / self.k;
                    let q = n / self.k;
                    let kf = self.k as f64;
                    let transforms =
                        (p + q) as f64 * (kf / 2.0) * kf.log2() * 5.0;
                    let ew = (p * q) as f64 * kf * 4.0;
                    circ_flops += transforms + ew;
                }
            }
        }
        circ_flops / dense_flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn google_total_params_match_table1() {
        // Table 1: block size 1 → 8.01M; 2 → 4.03M; 4 → 2.04M; 8 → 1.05M;
        // 16 → 0.55M. Padding makes ours differ by <2%.
        // Tolerances widen slightly with k: the paper's small-k rows are
        // sharp (8.01M → ours 7.98M) while the k=16 row is coarsely rounded
        // (0.55M vs an arithmetic 8.01M/16 + vectors ≈ 0.52M).
        let expect = [
            (1usize, 8.01e6, 0.02),
            (2, 4.03e6, 0.02),
            (4, 2.04e6, 0.03),
            (8, 1.05e6, 0.05),
            (16, 0.55e6, 0.08),
        ];
        for (k, target, tol) in expect {
            let got = LstmSpec::google(k).total_params() as f64;
            let rel = (got - target).abs() / target;
            assert!(
                rel < tol,
                "k={k}: got {got:.3e}, table says {target:.3e} (rel {rel:.3})"
            );
        }
    }

    #[test]
    fn google_layer1_matches_table3() {
        // Table 3: ESE 0.73M at 4.5:1 → dense layer-1 ≈ 3.25M;
        // C-LSTM FFT8 0.41M, FFT16 0.20M.
        let dense = LstmSpec::google(1).layer1_matrix_params() as f64;
        assert!((dense / 3.25e6 - 1.0).abs() < 0.02, "dense layer1 {dense:.3e}");
        let k8 = LstmSpec::google(8).layer1_matrix_params() as f64;
        assert!((k8 / 0.41e6 - 1.0).abs() < 0.03, "fft8 layer1 {k8:.3e}");
        let k16 = LstmSpec::google(16).layer1_matrix_params() as f64;
        assert!((k16 / 0.20e6 - 1.0).abs() < 0.06, "fft16 layer1 {k16:.3e}");
    }

    #[test]
    fn small_layer1_matches_table3() {
        // Table 3 Small LSTM: FFT8 0.28M, FFT16 0.14M.
        let k8 = LstmSpec::small(8).layer1_matrix_params() as f64;
        assert!((k8 / 0.28e6 - 1.0).abs() < 0.05, "small fft8 {k8:.3e}");
        let k16 = LstmSpec::small(16).layer1_matrix_params() as f64;
        assert!((k16 / 0.14e6 - 1.0).abs() < 0.05, "small fft16 {k16:.3e}");
    }

    #[test]
    fn compression_ratios_match_table3() {
        // Matrix compression ratio rows: 7.9:1 (k=8), 15.9:1 (k=16).
        // (Slightly below k because padding adds parameters.)
        let r8 = LstmSpec::google(8).matrix_stats().ratio();
        let r16 = LstmSpec::google(16).matrix_stats().ratio();
        assert!((7.5..=8.0).contains(&r8), "r8 {r8}");
        assert!((15.0..=16.0).contains(&r16), "r16 {r16}");
    }

    #[test]
    fn complexity_column_matches_table1() {
        // Table 1 normalized complexity: 1, 0.50, 0.50, 0.39, 0.27 for
        // k = 1, 2, 4, 8, 16 — the paper's op-count ratio.
        assert_eq!(LstmSpec::google(1).complexity_vs_dense(), 1.0);
        assert_eq!(LstmSpec::google(2).complexity_vs_dense(), 0.5);
        assert_eq!(LstmSpec::google(4).complexity_vs_dense(), 0.5);
        let c8 = LstmSpec::google(8).complexity_vs_dense();
        let c16 = LstmSpec::google(16).complexity_vs_dense();
        assert!((c8 - 0.39).abs() < 0.03, "c8 {c8}"); // 0.375
        assert!((c16 - 0.27).abs() < 0.03, "c16 {c16}"); // 0.25
    }

    #[test]
    fn flop_ratio_monotone_and_below_paper_metric() {
        let f2 = LstmSpec::google(2).flops_vs_dense();
        let f4 = LstmSpec::google(4).flops_vs_dense();
        let f8 = LstmSpec::google(8).flops_vs_dense();
        let f16 = LstmSpec::google(16).flops_vs_dense();
        assert!(f2 > f4 && f4 > f8 && f8 > f16, "{f2} {f4} {f8} {f16}");
        // Real flop savings are at least as good as the asymptotic metric.
        assert!(f8 <= LstmSpec::google(8).complexity_vs_dense() + 0.05);
        assert_eq!(LstmSpec::google(1).flops_vs_dense(), 1.0);
    }

    #[test]
    fn padding_rules() {
        let s = LstmSpec::google(8);
        assert_eq!(s.pad(153), 160);
        assert_eq!(s.pad(512), 512);
        assert_eq!(s.fused_in_dim(0), 160 + 512);
        assert_eq!(s.fused_in_dim(1), 512 + 512);
        let sm = LstmSpec::small(16);
        assert_eq!(sm.pad(39), 48);
        // Layer 2 of the bidirectional model sees both directions.
        assert_eq!(sm.layer_input_dim(1), 1024);
    }

    #[test]
    fn tiny_spec_consistent() {
        let t = LstmSpec::tiny(4);
        assert_eq!(t.out_dim(), 16);
        assert!(t.total_params() > 0);
        assert_eq!(t.directions(), 1);
    }
}
