//! Activation functions: exact and 22-segment piece-wise linear (§4.2, Fig 4).
//!
//! Transcendental activations are expensive on FPGAs; the paper replaces
//! them with quantised piece-wise linear (PWL) approximations — 22 segments,
//! "error rate less than 1 %", evaluated as one comparison (segment index),
//! one 16-bit multiply and one addition.
//!
//! Each segment uses the *minimax* (equioscillating) linear fit rather than
//! endpoint interpolation, which halves the worst-case error and is what
//! makes 22 segments sufficient for tanh. Outside the fitted range the
//! functions are clamped to their asymptotes.

use crate::num::fxp::{narrow, Q, Rounding};

/// Exact logistic sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Exact hyperbolic tangent.
#[inline]
pub fn tanh(x: f32) -> f32 {
    x.tanh()
}

/// Which activation implementation an engine uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActivationMode {
    /// Exact transcendental (reference).
    Exact,
    /// 22-segment piece-wise linear (the FPGA implementation).
    Pwl,
}

/// The number of segments used by the paper (Fig 4).
pub const PAPER_SEGMENTS: usize = 22;

/// A piece-wise linear approximation table with uniform segments.
///
/// Stores float slope/intercept pairs and their 16-bit quantised forms:
/// slopes in Q1.14 (|slope| ≤ 1 for σ and tanh), intercepts in the data
/// format. The fixed-point evaluation path is bit-accurate to the FPGA
/// datapath: segment index by comparison, one multiply, one add.
#[derive(Debug, Clone)]
pub struct PwlTable {
    pub x_min: f32,
    pub x_max: f32,
    pub segments: usize,
    /// Clamp values left/right of the fitted range.
    pub y_left: f32,
    pub y_right: f32,
    pub slope: Vec<f32>,
    pub intercept: Vec<f32>,
    /// Quantised slopes (Q1.14).
    pub slope_fx: Vec<i16>,
    /// Quantised intercepts (data format).
    pub intercept_fx: Vec<i16>,
    /// Data Q-format used by the fixed-point path.
    pub q_data: Q,
    inv_step: f32,
}

/// Q-format of the PWL slopes.
pub const SLOPE_Q: Q = Q::new(14);

impl PwlTable {
    /// Build a minimax-fit PWL table for `f` over `[x_min, x_max]` with
    /// `segments` uniform pieces, quantised against `q_data`.
    pub fn build(
        f: impl Fn(f64) -> f64,
        x_min: f32,
        x_max: f32,
        segments: usize,
        y_left: f32,
        y_right: f32,
        q_data: Q,
    ) -> Self {
        assert!(segments >= 1 && x_max > x_min);
        let h = (x_max - x_min) as f64 / segments as f64;
        let mut slope = Vec::with_capacity(segments);
        let mut intercept = Vec::with_capacity(segments);
        for s in 0..segments {
            let a = x_min as f64 + s as f64 * h;
            let b = a + h;
            let m = 0.5 * (a + b);
            let sl = (f(b) - f(a)) / h;
            // Equioscillating intercept: average of the endpoint-chord
            // intercept and the midpoint-tangent intercept. For a segment
            // where f has one sign of curvature this is the L∞-optimal
            // linear fit (error = h²·|f''|/16 instead of /8).
            let c_chord = f(a) - sl * a;
            let c_mid = f(m) - sl * m;
            let c = 0.5 * (c_chord + c_mid);
            slope.push(sl as f32);
            intercept.push(c as f32);
        }
        let slope_fx = slope.iter().map(|&s| SLOPE_Q.from_f32(s)).collect();
        let intercept_fx = intercept.iter().map(|&c| q_data.from_f32(c)).collect();
        Self {
            x_min,
            x_max,
            segments,
            y_left,
            y_right,
            slope,
            intercept,
            slope_fx,
            intercept_fx,
            q_data,
            inv_step: segments as f32 / (x_max - x_min),
        }
    }

    /// The paper's sigmoid table: 22 segments over [−8, 8] (Fig 4 left).
    pub fn sigmoid(q_data: Q) -> Self {
        Self::build(
            |x| 1.0 / (1.0 + (-x).exp()),
            -8.0,
            8.0,
            PAPER_SEGMENTS,
            0.0,
            1.0,
            q_data,
        )
    }

    /// The paper's tanh table: 22 segments over [−4, 4] (Fig 4 right —
    /// tanh saturates by ±4, so the fitted range is tighter).
    pub fn tanh(q_data: Q) -> Self {
        Self::build(|x| x.tanh(), -4.0, 4.0, PAPER_SEGMENTS, -1.0, 1.0, q_data)
    }

    /// Float evaluation.
    #[inline]
    pub fn eval(&self, x: f32) -> f32 {
        if x < self.x_min {
            return self.y_left;
        }
        if x >= self.x_max {
            return self.y_right;
        }
        let idx = ((x - self.x_min) * self.inv_step) as usize;
        let idx = idx.min(self.segments - 1);
        self.slope[idx] * x + self.intercept[idx]
    }

    /// Bit-accurate fixed-point evaluation: raw `i16` in the data format →
    /// raw `i16` in the data format. One comparison chain (here: integer
    /// divide by the segment width), one Q1.14 multiply, one saturating add.
    #[inline]
    pub fn eval_fx(&self, x: i16, rounding: Rounding) -> i16 {
        let x_min_raw = self.q_data.from_f32(self.x_min) as i32;
        let x_max_raw = self.q_data.from_f32(self.x_max) as i32;
        let xi = x as i32;
        if xi < x_min_raw {
            return self.q_data.from_f32(self.y_left);
        }
        if xi >= x_max_raw {
            return self.q_data.from_f32(self.y_right);
        }
        let span = (x_max_raw - x_min_raw) as i64;
        let idx = (((xi - x_min_raw) as i64 * self.segments as i64) / span) as usize;
        let idx = idx.min(self.segments - 1);
        // y = slope·x + intercept; slope in Q1.14, x in data format →
        // product has frac(data)+14 bits; narrow by 14 back to data format.
        let prod = self.slope_fx[idx] as i32 * x as i32;
        let term = narrow(prod, SLOPE_Q.frac, rounding);
        term.saturating_add(self.intercept_fx[idx])
    }

    /// Maximum absolute error of the float PWL over a dense grid — the
    /// quantity Fig 4 claims is below 1 %.
    pub fn max_error(&self, f: impl Fn(f64) -> f64) -> f64 {
        let mut worst = 0.0f64;
        let n = 20_000;
        // Probe beyond the fitted range to include clamp error.
        let lo = self.x_min as f64 - 4.0;
        let hi = self.x_max as f64 + 4.0;
        for i in 0..=n {
            let x = lo + (hi - lo) * i as f64 / n as f64;
            let approx = self.eval(x as f32) as f64;
            worst = worst.max((approx - f(x)).abs());
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const QD: Q = Q::new(12);

    #[test]
    fn paper_claim_sigmoid_error_below_1_percent() {
        let t = PwlTable::sigmoid(QD);
        let err = t.max_error(|x| 1.0 / (1.0 + (-x).exp()));
        assert!(err < 0.01, "sigmoid PWL max error {err}");
    }

    #[test]
    fn paper_claim_tanh_error_below_1_percent() {
        let t = PwlTable::tanh(QD);
        let err = t.max_error(|x| x.tanh());
        assert!(err < 0.01, "tanh PWL max error {err}");
    }

    #[test]
    fn minimax_beats_chord_interpolation() {
        // Same segment budget, chord fit (intercept through endpoints):
        let chord = {
            let h = 8.0f64 / PAPER_SEGMENTS as f64;
            let mut worst = 0.0f64;
            for s in 0..PAPER_SEGMENTS {
                let a = -4.0 + s as f64 * h;
                let b = a + h;
                let sl = (b.tanh() - a.tanh()) / h;
                let c = a.tanh() - sl * a;
                for i in 0..200 {
                    let x = a + h * i as f64 / 200.0;
                    worst = worst.max((sl * x + c - x.tanh()).abs());
                }
            }
            worst
        };
        let minimax = PwlTable::tanh(QD).max_error(|x| x.tanh());
        assert!(
            minimax < chord,
            "minimax {minimax} should beat chord {chord}"
        );
    }

    #[test]
    fn clamps_outside_range() {
        let t = PwlTable::sigmoid(QD);
        assert_eq!(t.eval(-100.0), 0.0);
        assert_eq!(t.eval(100.0), 1.0);
        let th = PwlTable::tanh(QD);
        assert_eq!(th.eval(-100.0), -1.0);
        assert_eq!(th.eval(100.0), 1.0);
    }

    #[test]
    fn fixed_point_matches_float_within_lsbs() {
        let t = PwlTable::sigmoid(QD);
        let th = PwlTable::tanh(QD);
        for i in -4000..4000 {
            let x = i as f32 * 0.002 * 4.0; // [-32, 32] → includes clamps
            let xq = QD.from_f32(x);
            for (tab, name) in [(&t, "sigmoid"), (&th, "tanh")] {
                let fx = QD.to_f32(tab.eval_fx(xq, Rounding::Nearest));
                let fl = tab.eval(QD.to_f32(xq));
                assert!(
                    (fx - fl).abs() <= 4.0 * QD.eps() as f32,
                    "{name}({x}): fx {fx} vs float {fl}"
                );
            }
        }
    }

    #[test]
    fn near_monotone_on_grid() {
        // σ is monotone; the minimax PWL has small jumps at segment
        // boundaries (bounded by ~2× the fit error) but no larger
        // violations, and is globally increasing.
        let t = PwlTable::sigmoid(QD);
        let mut prev = f32::MIN;
        for i in -1000..=1000 {
            let y = t.eval(i as f32 * 0.01);
            assert!(y >= prev - 8e-3, "x={}", i as f32 * 0.01);
            prev = y;
        }
        assert!(t.eval(8.0) > t.eval(-8.0) + 0.9);
    }

    #[test]
    fn odd_symmetry_of_tanh_table() {
        let t = PwlTable::tanh(QD);
        for i in 0..400 {
            let x = i as f32 * 0.01;
            let err = (t.eval(x) + t.eval(-x)).abs();
            assert!(err < 2e-2, "tanh symmetry at {x}: {err}");
        }
    }

    #[test]
    fn exact_helpers() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!((tanh(0.0)).abs() < 1e-7);
        assert!((sigmoid(4.0) + sigmoid(-4.0) - 1.0).abs() < 1e-6);
    }
}
