//! LSTM model definitions and inference engines (§2, §4.2).
//!
//! - [`config`] — [`LstmSpec`]: the two evaluated architectures (Google
//!   LSTM [25] with peepholes + projection; Small LSTM [20], bidirectional)
//!   plus parameter accounting that regenerates the Table 1 / Table 3
//!   "#parameters" columns.
//! - [`activations`] — exact σ/tanh and the 22-segment piece-wise-linear
//!   approximations of Fig 4 (float and bit-accurate fixed-point forms).
//! - [`weights`] — block-circulant weight bundles: init, save/load, and
//!   precomputed spectral forms for both engines.
//! - [`cell_f32`] — float inference engine (Eq 1a–1g) over the Eq 6
//!   optimized circulant convolution; the accuracy reference.
//! - [`cell_fxp`] — the bit-accurate 16-bit fixed-point engine: every
//!   multiply, add, shift and activation exactly as the FPGA datapath
//!   executes them.
//! - [`sequence`] — sequence/stack/bidirectional runners used by the PER
//!   evaluation and the serving pipeline.

pub mod activations;
pub mod cell_f32;
pub mod cell_fxp;
pub mod config;
pub mod sequence;
pub mod weights;

pub use activations::{sigmoid, tanh, ActivationMode, PwlTable};
pub use cell_f32::CellF32;
pub use cell_fxp::CellFx;
pub use config::{LstmSpec, ModelKind};
pub use sequence::{run_sequence_f32, run_stack_f32, StackF32};
pub use weights::{LayerWeights, LstmWeights};
