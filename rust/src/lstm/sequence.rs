//! Sequence runners: stacked / bidirectional execution, classifier head,
//! greedy framewise decoding (used by the PER evaluation of §3.3/§6).

use super::activations::ActivationMode;
use super::cell_f32::CellF32;
use super::cell_fxp::CellFx;
use super::config::LstmSpec;
use super::weights::LstmWeights;
use crate::num::fxp::{Q, Rounding};

/// A ready-to-run float model: all layers/directions with precomputed
/// spectra, plus the classifier head.
pub struct StackF32 {
    pub spec: LstmSpec,
    /// `cells[l][d]`.
    cells: Vec<Vec<CellF32>>,
    classifier: Option<(Vec<f32>, Vec<f32>)>,
}

impl StackF32 {
    pub fn new(w: &LstmWeights, mode: ActivationMode) -> Self {
        let cells = w
            .layers
            .iter()
            .enumerate()
            .map(|(l, dirs)| {
                dirs.iter()
                    .map(|lw| CellF32::new(&w.spec, l, lw, mode))
                    .collect()
            })
            .collect();
        Self {
            spec: w.spec.clone(),
            cells,
            classifier: w.classifier.clone(),
        }
    }

    /// Run a full utterance: `frames[t]` is the feature vector at time `t`.
    /// Returns per-frame final-layer outputs (concatenated over directions).
    pub fn run(&self, frames: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut inputs: Vec<Vec<f32>> = frames.to_vec();
        for (l, dirs) in self.cells.iter().enumerate() {
            let _ = l;
            let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); inputs.len()];
            // Forward direction.
            let fwd = &dirs[0];
            let mut st = fwd.zero_state();
            for (t, x) in inputs.iter().enumerate() {
                let y = fwd.step(x, &mut st);
                outputs[t].extend_from_slice(&y[..self.spec.out_dim()]);
            }
            // Backward direction (bidirectional): reversed time, outputs
            // concatenated feature-wise.
            if dirs.len() > 1 {
                let bwd = &dirs[1];
                let mut st = bwd.zero_state();
                let mut rev: Vec<Vec<f32>> = Vec::with_capacity(inputs.len());
                for x in inputs.iter().rev() {
                    let y = bwd.step(x, &mut st);
                    rev.push(y[..self.spec.out_dim()].to_vec());
                }
                for (t, y) in rev.into_iter().rev().enumerate() {
                    outputs[t].extend_from_slice(&y);
                }
            }
            inputs = outputs;
        }
        inputs
    }

    /// Per-frame class logits.
    pub fn logits(&self, frames: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let outs = self.run(frames);
        let (w, b) = self
            .classifier
            .as_ref()
            .expect("spec.num_classes == 0: no classifier head");
        let n_cls = b.len();
        outs.into_iter()
            .map(|o| {
                (0..n_cls)
                    .map(|c| {
                        b[c] + o
                            .iter()
                            .enumerate()
                            .map(|(j, &v)| w[c * o.len() + j] * v)
                            .sum::<f32>()
                    })
                    .collect()
            })
            .collect()
    }

    /// Greedy framewise decode → per-frame class ids.
    pub fn decode(&self, frames: &[Vec<f32>]) -> Vec<usize> {
        self.logits(frames)
            .into_iter()
            .map(|l| argmax(&l))
            .collect()
    }
}

/// Fixed-point stack mirroring [`StackF32`] (classifier head evaluated in
/// float on the dequantised outputs — on the FPGA the tiny softmax head
/// runs on the host, as in ESE).
pub struct StackFx {
    pub spec: LstmSpec,
    cells: Vec<Vec<CellFx>>,
    classifier: Option<(Vec<f32>, Vec<f32>)>,
    q: Q,
}

impl StackFx {
    pub fn new(w: &LstmWeights, q: Q) -> Self {
        Self::with_rounding(w, q, Rounding::Nearest)
    }

    /// As [`Self::new`] with an explicit narrowing policy (§4.2 shift-policy
    /// ablation) — the oracle counterpart of serving with
    /// `clstm serve --backend fxp --rounding truncate`.
    pub fn with_rounding(w: &LstmWeights, q: Q, rounding: Rounding) -> Self {
        let cells = w
            .layers
            .iter()
            .enumerate()
            .map(|(l, dirs)| {
                dirs.iter()
                    .map(|lw| CellFx::with_rounding(&w.spec, l, lw, q, rounding))
                    .collect()
            })
            .collect();
        Self {
            spec: w.spec.clone(),
            cells,
            classifier: w.classifier.clone(),
            q,
        }
    }

    /// Run a full utterance in fixed point; returns dequantised outputs.
    pub fn run(&self, frames: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut inputs: Vec<Vec<i16>> = frames
            .iter()
            .map(|f| self.q.quantize_slice(f))
            .collect();
        for dirs in self.cells.iter() {
            let mut outputs: Vec<Vec<i16>> = vec![Vec::new(); inputs.len()];
            let fwd = &dirs[0];
            let mut st = fwd.zero_state();
            for (t, x) in inputs.iter().enumerate() {
                let y = fwd.step(x, &mut st);
                outputs[t].extend_from_slice(&y[..self.spec.out_dim()]);
            }
            if dirs.len() > 1 {
                let bwd = &dirs[1];
                let mut st = bwd.zero_state();
                let mut rev: Vec<Vec<i16>> = Vec::with_capacity(inputs.len());
                for x in inputs.iter().rev() {
                    let y = bwd.step(x, &mut st);
                    rev.push(y[..self.spec.out_dim()].to_vec());
                }
                for (t, y) in rev.into_iter().rev().enumerate() {
                    outputs[t].extend_from_slice(&y);
                }
            }
            inputs = outputs;
        }
        inputs
            .into_iter()
            .map(|o| self.q.dequantize_slice(&o))
            .collect()
    }

    /// Greedy framewise decode.
    pub fn decode(&self, frames: &[Vec<f32>]) -> Vec<usize> {
        let outs = self.run(frames);
        let (w, b) = self
            .classifier
            .as_ref()
            .expect("no classifier head");
        let n_cls = b.len();
        outs.into_iter()
            .map(|o| {
                let logits: Vec<f32> = (0..n_cls)
                    .map(|c| {
                        b[c] + o
                            .iter()
                            .enumerate()
                            .map(|(j, &v)| w[c * o.len() + j] * v)
                            .sum::<f32>()
                    })
                    .collect();
                argmax(&logits)
            })
            .collect()
    }
}

/// Index of the maximum element.
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Convenience: run one float sequence through a freshly-built stack.
pub fn run_sequence_f32(w: &LstmWeights, frames: &[Vec<f32>]) -> Vec<Vec<f32>> {
    StackF32::new(w, ActivationMode::Exact).run(frames)
}

/// Convenience: build + decode.
pub fn run_stack_f32(w: &LstmWeights, frames: &[Vec<f32>]) -> Vec<usize> {
    StackF32::new(w, ActivationMode::Exact).decode(frames)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn frames(spec: &LstmSpec, t: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..t)
            .map(|_| {
                (0..spec.input_dim)
                    .map(|_| rng.uniform(-1.0, 1.0) as f32)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn unidirectional_shapes() {
        let spec = LstmSpec::tiny(4);
        let w = LstmWeights::random(&spec, 1);
        let outs = run_sequence_f32(&w, &frames(&spec, 7, 2));
        assert_eq!(outs.len(), 7);
        assert_eq!(outs[0].len(), spec.out_dim());
    }

    #[test]
    fn bidirectional_concat_shapes() {
        let mut spec = LstmSpec::small(4);
        spec.hidden_dim = 32;
        spec.input_dim = 8;
        spec.layers = 2;
        let w = LstmWeights::random(&spec, 3);
        let stack = StackF32::new(&w, ActivationMode::Exact);
        let outs = stack.run(&frames(&spec, 5, 4));
        assert_eq!(outs.len(), 5);
        assert_eq!(outs[0].len(), 2 * spec.out_dim());
    }

    #[test]
    fn bidirectional_sees_future_context() {
        // Changing the LAST frame must change the FIRST frame's output in a
        // bidirectional stack (and must not in a unidirectional one).
        let mut spec = LstmSpec::small(2);
        spec.hidden_dim = 16;
        spec.input_dim = 4;
        spec.layers = 1;
        let w = LstmWeights::random(&spec, 5);
        let stack = StackF32::new(&w, ActivationMode::Exact);
        let mut f1 = frames(&spec, 6, 6);
        let o1 = stack.run(&f1);
        for v in f1.last_mut().unwrap().iter_mut() {
            *v += 1.0;
        }
        let o2 = stack.run(&f1);
        let first_diff: f32 = o1[0].iter().zip(&o2[0]).map(|(a, b)| (a - b).abs()).sum();
        assert!(first_diff > 1e-6, "bwd direction must propagate future");
    }

    #[test]
    fn unidirectional_is_causal() {
        let spec = LstmSpec::tiny(4);
        let w = LstmWeights::random(&spec, 7);
        let stack = StackF32::new(&w, ActivationMode::Exact);
        let mut f = frames(&spec, 6, 8);
        let o1 = stack.run(&f);
        for v in f.last_mut().unwrap().iter_mut() {
            *v += 1.0;
        }
        let o2 = stack.run(&f);
        for t in 0..5 {
            let d: f32 = o1[t].iter().zip(&o2[t]).map(|(a, b)| (a - b).abs()).sum();
            assert!(d == 0.0, "causality violated at t={t}");
        }
    }

    #[test]
    fn decode_yields_valid_classes() {
        let spec = LstmSpec::tiny(2);
        let w = LstmWeights::random(&spec, 9);
        let ids = run_stack_f32(&w, &frames(&spec, 10, 10));
        assert_eq!(ids.len(), 10);
        assert!(ids.iter().all(|&c| c < spec.num_classes));
    }

    #[test]
    fn fxp_stack_tracks_float_stack_decisions() {
        let spec = LstmSpec::tiny(4);
        let w = LstmWeights::random(&spec, 11);
        let fs = frames(&spec, 12, 12);
        let float_ids = StackF32::new(&w, ActivationMode::Pwl).decode(&fs);
        let fx_ids = StackFx::new(&w, Q::new(12)).decode(&fs);
        let agree = float_ids
            .iter()
            .zip(&fx_ids)
            .filter(|(a, b)| a == b)
            .count();
        // Quantisation may flip the odd borderline frame but most agree.
        assert!(
            agree * 10 >= float_ids.len() * 8,
            "only {agree}/{} frames agree",
            float_ids.len()
        );
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[1.0]), 0);
    }
}
