//! Bit-accurate 16-bit fixed-point LSTM cell (§4.2) — the datapath the
//! generated FPGA design executes, modelled operation-for-operation.
//!
//! Everything is 16-bit: gate mat-vecs run through [`FxConvPlan`] (FFT with
//! DFT-side distributed shifts, saturating frequency-domain accumulation),
//! activations through the quantised 22-segment PWL tables, element-wise
//! products through single Q-format multiplies with round-to-nearest
//! narrowing. The only f32 touchpoints are the quantise/dequantise
//! boundaries.

use super::activations::{PwlTable, SLOPE_Q};
use super::config::LstmSpec;
use super::weights::{LayerWeights, GATE_F, GATE_G, GATE_I, GATE_O};
use crate::analysis::ir::{DeclareOps, GraphBuilder, NodeId, OpKind, SatRole};
use crate::circulant::fxp_conv::{FxConvPlan, FxConvScratch};
use std::cell::RefCell;
use crate::circulant::spectral::{SpectralWeights, SpectralWeightsFx};
use crate::num::fxp::{Q, Rounding};

/// The §4.2 element-wise cluster (Eq 1a–1f) on the 16-bit datapath:
/// saturating pre-activation adds (the FPGA adder tree), quantised PWL
/// activations, single Q-format multiplies with configurable narrowing.
///
/// This is the **single implementation** shared by the [`CellFx`] oracle
/// and the serving backend's stage-2 executor
/// ([`FxpStage2`](crate::runtime::fxp)), so backend/oracle bit-identity is
/// true by construction across every layer and direction — not merely
/// pinned by golden tests.
pub struct FxElementwise<'a> {
    pub q: Q,
    pub rounding: Rounding,
    /// Gate biases in `i, f, g, o` order (length ≥ `h` each).
    pub bias: &'a [Vec<i16>; 4],
    /// Peephole vectors `w_ic, w_fc, w_oc`, when the spec has them.
    pub peephole: Option<&'a [Vec<i16>; 3]>,
    pub pwl_sigmoid: &'a PwlTable,
    pub pwl_tanh: &'a PwlTable,
}

impl FxElementwise<'_> {
    /// One frame of the element-wise cluster over `h` cells: gate
    /// pre-activations `a` (in `i, f, g, o` order, length ≥ `h` each) in,
    /// cell output written to `m[..h]`, and the cell state `c` updated **in
    /// place** — read as `c_{t-1}`, left as `c_t` (each element is read
    /// before it is written, so no separate output buffer is needed).
    pub fn step(&self, h: usize, a: [&[i16]; 4], m: &mut [i16], c: &mut [i16]) {
        let q = self.q;
        let r = self.rounding;
        let [a_i, a_f, a_g, a_o] = a;
        for n in 0..h {
            let peep_term = |idx: usize, c_val: i16| -> i16 {
                match self.peephole {
                    Some(p) => q.mul(p[idx][n], c_val, r),
                    None => 0,
                }
            };
            let c_prev = c[n];
            // Pre-activations: saturating 16-bit adds (FPGA adder tree).
            let zi = a_i[n]
                .saturating_add(peep_term(0, c_prev))
                .saturating_add(self.bias[GATE_I][n]);
            let zf = a_f[n]
                .saturating_add(peep_term(1, c_prev))
                .saturating_add(self.bias[GATE_F][n]);
            let zg = a_g[n].saturating_add(self.bias[GATE_G][n]);

            let i = self.pwl_sigmoid.eval_fx(zi, r);
            let f = self.pwl_sigmoid.eval_fx(zf, r);
            let g = self.pwl_tanh.eval_fx(zg, r);

            // Eq 1d: c = f⊙c_prev + g⊙i, two Q multiplies + saturating add.
            let cn = q.mul(f, c_prev, r).saturating_add(q.mul(g, i, r));

            let zo = a_o[n]
                .saturating_add(peep_term(2, cn))
                .saturating_add(self.bias[GATE_O][n]);
            let o = self.pwl_sigmoid.eval_fx(zo, r);

            // Eq 1f.
            m[n] = q.mul(o, self.pwl_tanh.eval_fx(cn, r), r);
            c[n] = cn;
        }
    }
}

/// Declare one PWL lookup site class with the table's *measured* slope and
/// output envelopes.
fn declare_pwl(
    g: &mut GraphBuilder,
    site: &str,
    table: &PwlTable,
    frac: u32,
    budgeted: bool,
    input: NodeId,
) -> NodeId {
    let slope_bound = table
        .slope
        .iter()
        .fold(0f64, |m, &s| m.max(s.abs() as f64));
    let out_bound = table.y_left.abs().max(table.y_right.abs()) as f64;
    g.node(
        site,
        OpKind::Pwl {
            domain: table.x_max as f64,
            slope_frac: SLOPE_Q.frac,
            slope_bound,
            out_bound,
            budgeted,
        },
        frac,
        SatRole::Clamp,
        &[input],
    )
}

impl DeclareOps for FxElementwise<'_> {
    /// Declares one `step` iteration (Eq 1a–1f). Inputs: the four gate
    /// conv outputs `[a_i, a_f, a_g, a_o]` plus the stored cell state
    /// `c_prev`; outputs `[m, c]`.
    ///
    /// Error-reset convention: every *stored-state read* is a fresh
    /// [`OpKind::Source`] carrying only quantisation error — the verifier
    /// bounds the error injected per pass, while recurrent compounding
    /// across frames is the dynamic PER regression's contract. This is
    /// also why the output-gate peephole (which runs on the just-computed
    /// `c_t`) reads a fresh rail-bounded `c_store` source, and why only the
    /// gate pre-activation lookups are E4-`budgeted`.
    fn declare_ops(&self, g: &mut GraphBuilder, inputs: &[NodeId]) -> Vec<NodeId> {
        let q = self.q;
        let frac = q.frac;
        let (a_i, a_f, a_g, a_o, c_prev) =
            (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
        // Measured max-abs of a quantised vector, in real units.
        let vmax = |v: &[i16]| {
            v.iter().map(|&x| u32::from(x.unsigned_abs())).max().unwrap_or(0) as f64 * q.eps()
        };

        let bias = |g: &mut GraphBuilder, gate: usize, name: &str| {
            g.source(&format!("bias_{name}"), q, vmax(&self.bias[gate]))
        };
        // Peephole term w ⊙ c (`Q.mul`): a data-format product.
        let peep = |g: &mut GraphBuilder, idx: usize, name: &str, c: NodeId| {
            self.peephole.map(|p| {
                let w = g.source(&format!("peep_{name}"), q, vmax(&p[idx]));
                g.node(
                    &format!("peep_{name}_mul"),
                    OpKind::MulData,
                    frac,
                    SatRole::Tolerated,
                    &[w, c],
                )
            })
        };
        let preact = |g: &mut GraphBuilder,
                      name: &str,
                      a: NodeId,
                      peep_term: Option<NodeId>,
                      b: NodeId| {
            let mut ins = vec![a];
            ins.extend(peep_term);
            ins.push(b);
            g.node(&format!("z_{name}"), OpKind::AddSat, frac, SatRole::Tolerated, &ins)
        };

        let b_i = bias(g, GATE_I, "i");
        let p_i = peep(g, 0, "i", c_prev);
        let zi = preact(g, "i", a_i, p_i, b_i);
        let i_gate = declare_pwl(g, "sigmoid_i", self.pwl_sigmoid, frac, true, zi);

        let b_f = bias(g, GATE_F, "f");
        let p_f = peep(g, 1, "f", c_prev);
        let zf = preact(g, "f", a_f, p_f, b_f);
        let f_gate = declare_pwl(g, "sigmoid_f", self.pwl_sigmoid, frac, true, zf);

        let b_g = bias(g, GATE_G, "g");
        let zg = preact(g, "g", a_g, None, b_g);
        let g_gate = declare_pwl(g, "tanh_g", self.pwl_tanh, frac, true, zg);

        // Eq 1d: c = f⊙c_prev + g⊙i.
        let fc = g.node("f_x_c", OpKind::MulData, frac, SatRole::Tolerated, &[f_gate, c_prev]);
        let gi = g.node("g_x_i", OpKind::MulData, frac, SatRole::Tolerated, &[g_gate, i_gate]);
        let c = g.node("c", OpKind::AddSat, frac, SatRole::Tolerated, &[fc, gi]);

        let b_o = bias(g, GATE_O, "o");
        let p_o = if self.peephole.is_some() {
            let c_store = g.source("c_store", q, q.max_val());
            peep(g, 2, "o", c_store)
        } else {
            None
        };
        let zo = preact(g, "o", a_o, p_o, b_o);
        let o_gate = declare_pwl(g, "sigmoid_o", self.pwl_sigmoid, frac, true, zo);

        // Eq 1f: m = o ⊙ tanh(c). `tanh_c`'s input error is state-coupled,
        // hence un-budgeted (see above).
        let tanh_c = declare_pwl(g, "tanh_c", self.pwl_tanh, frac, false, c);
        let m = g.node("m", OpKind::MulData, frac, SatRole::Tolerated, &[o_gate, tanh_c]);
        vec![m, c]
    }
}

/// Fixed-point cell: one direction of one layer.
pub struct CellFx {
    pub spec: LstmSpec,
    pub layer: usize,
    /// Data Q-format (activations, cell state, inputs, outputs).
    pub q: Q,
    gates: [FxConvPlan; 4],
    /// Reusable conv scratch (§Perf: one allocation per cell, not per step).
    scratch: RefCell<FxConvScratch>,
    gate_out: RefCell<[Vec<i16>; 4]>,
    proj_scratch: RefCell<Option<FxConvScratch>>,
    bias: [Vec<i16>; 4],
    peephole: Option<[Vec<i16>; 3]>,
    proj: Option<FxConvPlan>,
    pwl_sigmoid: PwlTable,
    pwl_tanh: PwlTable,
    rounding: Rounding,
    in_pad: usize,
    out_pad: usize,
}

/// Fixed-point recurrent state.
#[derive(Debug, Clone)]
pub struct CellStateFx {
    pub y: Vec<i16>,
    pub c: Vec<i16>,
}

impl CellFx {
    /// Quantise layer weights into a ready-to-run fixed-point cell with the
    /// default round-to-nearest narrowing.
    ///
    /// `q` is the data format (Q3.12 by default from the range analysis);
    /// spectral weight formats are chosen per matrix by range analysis.
    pub fn new(spec: &LstmSpec, layer: usize, w: &LayerWeights, q: Q) -> Self {
        Self::with_rounding(spec, layer, w, q, Rounding::Nearest)
    }

    /// As [`Self::new`] with an explicit narrowing policy — the §4.2
    /// shift-policy ablation (`Rounding::Truncate` drops the rounding add
    /// after every distributed shift, as a plain `>>` datapath would).
    pub fn with_rounding(
        spec: &LstmSpec,
        layer: usize,
        w: &LayerWeights,
        q: Q,
        rounding: Rounding,
    ) -> Self {
        let mk_plan = |m: &crate::circulant::BlockCirculant| {
            let spec_f = SpectralWeights::precompute(m);
            let fx = SpectralWeightsFx::quantize_auto(&spec_f);
            FxConvPlan::new(fx, q, rounding)
        };
        let gates = [
            mk_plan(&w.gates[0]),
            mk_plan(&w.gates[1]),
            mk_plan(&w.gates[2]),
            mk_plan(&w.gates[3]),
        ];
        let gate_len = gates[0].weights.p * gates[0].weights.k;
        let scratch = RefCell::new(FxConvScratch::for_plan(&gates[0]));
        let gate_out = RefCell::new([
            vec![0i16; gate_len],
            vec![0i16; gate_len],
            vec![0i16; gate_len],
            vec![0i16; gate_len],
        ]);
        let proj_plan = w.proj.as_ref().map(|m| mk_plan(m));
        let proj_scratch = RefCell::new(proj_plan.as_ref().map(FxConvScratch::for_plan));
        Self {
            spec: spec.clone(),
            layer,
            q,
            gates,
            scratch,
            gate_out,
            proj_scratch,
            bias: [
                q.quantize_slice(&w.bias[0]),
                q.quantize_slice(&w.bias[1]),
                q.quantize_slice(&w.bias[2]),
                q.quantize_slice(&w.bias[3]),
            ],
            peephole: w
                .peephole
                .as_ref()
                .map(|p| [q.quantize_slice(&p[0]), q.quantize_slice(&p[1]), q.quantize_slice(&p[2])]),
            proj: proj_plan,
            pwl_sigmoid: PwlTable::sigmoid(q),
            pwl_tanh: PwlTable::tanh(q),
            rounding,
            in_pad: spec.pad(spec.layer_input_dim(layer)),
            out_pad: spec.pad(spec.out_dim()),
        }
    }

    /// Fresh zero state.
    pub fn zero_state(&self) -> CellStateFx {
        CellStateFx {
            y: vec![0; self.out_pad],
            c: vec![0; self.spec.hidden_dim],
        }
    }

    /// One step over raw fixed-point input (length ≤ padded input dim).
    /// Returns the padded output vector.
    pub fn step(&self, x: &[i16], state: &mut CellStateFx) -> Vec<i16> {
        let h = self.spec.hidden_dim;
        let q = self.q;
        let r = self.rounding;
        let mut fused = vec![0i16; self.in_pad + self.out_pad];
        fused[..x.len()].copy_from_slice(x);
        fused[self.in_pad..self.in_pad + state.y.len()].copy_from_slice(&state.y);

        let mut scratch = self.scratch.borrow_mut();
        let mut gate_out = self.gate_out.borrow_mut();
        {
            let (first, rest) = gate_out.split_at_mut(1);
            let (second, rest2) = rest.split_at_mut(1);
            let (third, fourth) = rest2.split_at_mut(1);
            // Buffer shapes are fixed at construction, so a length error
            // here is a cell bug, not a caller input.
            self.gates[GATE_I]
                .matvec_into(&fused, &mut first[0], &mut scratch)
                .expect("gate i conv");
            self.gates[GATE_F]
                .matvec_into(&fused, &mut second[0], &mut scratch)
                .expect("gate f conv");
            self.gates[GATE_G]
                .matvec_into(&fused, &mut third[0], &mut scratch)
                .expect("gate g conv");
            self.gates[GATE_O]
                .matvec_into(&fused, &mut fourth[0], &mut scratch)
                .expect("gate o conv");
        }
        // The element-wise cluster — the one implementation shared with the
        // serving backend's stage 2 ([`FxElementwise`]); updates state.c in
        // place. (`m` is a fresh vector because it becomes the return value
        // on the no-projection path, exactly as before.)
        let mut m = vec![0i16; self.gates[GATE_I].weights.p * self.gates[GATE_I].weights.k];
        FxElementwise {
            q,
            rounding: r,
            bias: &self.bias,
            peephole: self.peephole.as_ref(),
            pwl_sigmoid: &self.pwl_sigmoid,
            pwl_tanh: &self.pwl_tanh,
        }
        .step(
            h,
            [
                &gate_out[GATE_I][..],
                &gate_out[GATE_F][..],
                &gate_out[GATE_G][..],
                &gate_out[GATE_O][..],
            ],
            &mut m,
            &mut state.c,
        );

        let y = match &self.proj {
            Some(p) => {
                let mut ps = self.proj_scratch.borrow_mut();
                let scratch = ps.as_mut().expect("proj scratch");
                let mut out = vec![0i16; p.weights.p * p.weights.k];
                p.matvec_into(&m, &mut out, scratch).expect("projection conv");
                out
            }
            None => m,
        };
        let copy_len = self.out_pad.min(y.len());
        state.y[..copy_len].copy_from_slice(&y[..copy_len]);
        y
    }

    /// Float convenience wrapper: quantise input, step, dequantise output.
    pub fn step_f32(&self, x: &[f32], state: &mut CellStateFx) -> Vec<f32> {
        let xq = self.q.quantize_slice(x);
        self.q.dequantize_slice(&self.step(&xq, state))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::activations::ActivationMode;
    use crate::lstm::cell_f32::CellF32;
    use crate::lstm::weights::LstmWeights;
    use crate::util::prng::Xoshiro256;

    const QD: Q = Q::new(12);

    fn pair(k: usize, seed: u64) -> (LstmSpec, CellF32, CellFx) {
        let spec = LstmSpec::tiny(k);
        let w = LstmWeights::random(&spec, seed);
        let f = CellF32::new(&spec, 0, &w.layers[0][0], ActivationMode::Pwl);
        let x = CellFx::new(&spec, 0, &w.layers[0][0], QD);
        (spec, f, x)
    }

    #[test]
    fn tracks_float_engine_over_sequence() {
        let (spec, fcell, xcell) = pair(4, 21);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let mut sf = fcell.zero_state();
        let mut sx = xcell.zero_state();
        let mut worst = 0.0f32;
        for _ in 0..30 {
            let x: Vec<f32> = (0..spec.input_dim)
                .map(|_| rng.uniform(-1.0, 1.0) as f32)
                .collect();
            let yf = fcell.step(&x, &mut sf);
            let yx = xcell.step_f32(&x, &mut sx);
            for (a, b) in yf.iter().zip(&yx) {
                worst = worst.max((a - b).abs());
            }
        }
        // 16-bit datapath drift over 30 recurrent steps stays small; the
        // paper's observation that 16 bits is "accurate enough".
        assert!(worst < 0.05, "fxp drift {worst}");
    }

    #[test]
    fn deterministic_and_pure_fixed_point() {
        let (spec, _f, xcell) = pair(8, 3);
        let x: Vec<i16> = (0..spec.input_dim)
            .map(|i| i16::try_from(i % 7).unwrap() * 400)
            .collect();
        let mut s1 = xcell.zero_state();
        let mut s2 = xcell.zero_state();
        let y1 = xcell.step(&x, &mut s1);
        let y2 = xcell.step(&x, &mut s2);
        assert_eq!(y1, y2);
        assert_eq!(s1.c, s2.c);
    }

    #[test]
    fn saturation_not_wraparound_on_hot_inputs() {
        let (spec, _f, xcell) = pair(4, 4);
        // Near-max inputs: outputs must stay in range (no wrap to negative).
        let x = vec![i16::MAX - 1; spec.input_dim];
        let mut s = xcell.zero_state();
        for _ in 0..5 {
            let y = xcell.step(&x, &mut s);
            // m = o·tanh(c) is bounded by 1 in float; in Q3.12, |y| of the
            // projection of bounded m stays well below saturation unless
            // wrap-around corrupted the datapath.
            assert!(y.iter().all(|&v| v > i16::MIN + 8));
        }
    }

    #[test]
    fn k1_and_k8_both_run() {
        for k in [1usize, 2, 8] {
            let (spec, _f, xcell) = pair(k, 9);
            let x = vec![1000i16; spec.input_dim];
            let mut s = xcell.zero_state();
            let y = xcell.step(&x, &mut s);
            assert_eq!(y.len(), spec.pad(spec.out_dim()));
        }
    }
}
