//! Range analysis and Q-format selection (§4.2).
//!
//! "We first analyze the numerical range of the trained weights in the
//! LSTM, and then determine the bitwidth of integer and fractional parts to
//! avoid data overflow and accuracy degradation."
//!
//! [`RangeTracker`] accumulates min/max/mean/rms per tensor class;
//! [`FormatReport`] turns the observed ranges into Q-format
//! recommendations and quantisation-SNR estimates.

use crate::num::fxp::{quant_snr_db, Q};
use std::collections::BTreeMap;

/// Running statistics of one tensor class.
#[derive(Debug, Clone)]
pub struct RangeStats {
    pub count: u64,
    pub min: f64,
    pub max: f64,
    pub sum_abs: f64,
    pub sum_sq: f64,
    /// Reservoir of samples for SNR estimation.
    samples: Vec<f32>,
}

impl Default for RangeStats {
    fn default() -> Self {
        Self {
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum_abs: 0.0,
            sum_sq: 0.0,
            samples: Vec::new(),
        }
    }
}

impl RangeStats {
    pub fn absmax(&self) -> f64 {
        self.min.abs().max(self.max.abs()).max(0.0)
    }

    pub fn rms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            (self.sum_sq / self.count as f64).sqrt()
        }
    }

    /// Smallest-`frac` format whose range covers `absmax` with `headroom`
    /// extra integer bits (headroom absorbs inputs hotter than calibration).
    pub fn recommend(&self, headroom: u32) -> Q {
        let am = self.absmax().max(1e-12);
        // Need 2^(15−frac) > am·2^headroom.
        let int_bits = am.log2().ceil().max(0.0) as i64 + headroom as i64;
        let frac = (15 - int_bits).clamp(0, 15) as u32;
        Q::new(frac)
    }
}

/// Tracks many named tensor classes during a calibration run.
#[derive(Debug, Default)]
pub struct RangeTracker {
    stats: BTreeMap<String, RangeStats>,
    /// Max samples kept per class for SNR estimation.
    reservoir: usize,
}

impl RangeTracker {
    pub fn new() -> Self {
        Self {
            stats: BTreeMap::new(),
            reservoir: 8192,
        }
    }

    /// Record a batch of values for a class.
    pub fn observe(&mut self, class: &str, values: &[f32]) {
        let s = self.stats.entry(class.to_string()).or_default();
        for &v in values {
            let vf = v as f64;
            s.count += 1;
            s.min = s.min.min(vf);
            s.max = s.max.max(vf);
            s.sum_abs += vf.abs();
            s.sum_sq += vf * vf;
            if s.samples.len() < self.reservoir {
                s.samples.push(v);
            }
        }
    }

    pub fn get(&self, class: &str) -> Option<&RangeStats> {
        self.stats.get(class)
    }

    /// Produce the per-class format report with `headroom` integer bits.
    pub fn report(&self, headroom: u32) -> FormatReport {
        let entries = self
            .stats
            .iter()
            .map(|(name, s)| {
                let q = s.recommend(headroom);
                let snr = if s.samples.is_empty() {
                    f64::INFINITY
                } else {
                    quant_snr_db(q, &s.samples)
                };
                FormatEntry {
                    class: name.clone(),
                    absmax: s.absmax(),
                    rms: s.rms(),
                    q,
                    snr_db: snr,
                }
            })
            .collect();
        FormatReport { entries }
    }
}

/// One class's recommendation.
#[derive(Debug, Clone)]
pub struct FormatEntry {
    pub class: String,
    pub absmax: f64,
    pub rms: f64,
    pub q: Q,
    pub snr_db: f64,
}

/// The full report; also picks the single *datapath* format (the paper uses
/// one 16-bit format for the shared datapath) as the minimum-frac
/// recommendation across activation-like classes.
#[derive(Debug, Clone)]
pub struct FormatReport {
    pub entries: Vec<FormatEntry>,
}

impl FormatReport {
    /// The shared datapath format: min fractional bits over all classes
    /// (covers the widest range seen anywhere).
    pub fn datapath_format(&self) -> Q {
        self.entries
            .iter()
            .map(|e| e.q)
            .min_by_key(|q| q.frac)
            .unwrap_or(Q::new(12))
    }

    /// Render as an aligned text table.
    pub fn to_table(&self) -> String {
        let mut s = String::from(
            "class                          absmax        rms     format   SNR(dB)\n",
        );
        for e in &self.entries {
            s.push_str(&format!(
                "{:<28} {:>9.4} {:>10.5}   Q{}.{:<2} {:>9.1}\n",
                e.class,
                e.absmax,
                e.rms,
                15 - e.q.frac,
                e.q.frac,
                e.snr_db
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn recommends_wider_int_for_wider_range() {
        let mut t = RangeTracker::new();
        t.observe("small", &[0.1, -0.2, 0.05]);
        t.observe("big", &[30.0, -12.0, 4.0]);
        let r = t.report(0);
        let small = r.entries.iter().find(|e| e.class == "small").unwrap();
        let big = r.entries.iter().find(|e| e.class == "big").unwrap();
        assert!(small.q.frac > big.q.frac);
        // Ranges actually covered.
        assert!(small.q.max_val() >= 0.2);
        assert!(big.q.max_val() >= 30.0);
    }

    #[test]
    fn headroom_reduces_frac() {
        let mut t = RangeTracker::new();
        t.observe("x", &[1.5, -1.0]);
        let r0 = t.report(0).entries[0].q;
        let r2 = t.report(2).entries[0].q;
        assert!(r2.frac < r0.frac);
    }

    #[test]
    fn datapath_format_is_min_frac() {
        let mut t = RangeTracker::new();
        t.observe("a", &[0.1]);
        t.observe("b", &[100.0]);
        let r = t.report(0);
        let dp = r.datapath_format();
        let bq = r.entries.iter().find(|e| e.class == "b").unwrap().q;
        assert_eq!(dp.frac, bq.frac);
    }

    #[test]
    fn snr_reported_for_gaussian_data() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let data: Vec<f32> = (0..5000).map(|_| rng.normal() as f32).collect();
        let mut t = RangeTracker::new();
        t.observe("g", &data);
        let r = t.report(1);
        // 16-bit fixed point on unit-variance data: SNR well above 40 dB.
        assert!(r.entries[0].snr_db > 40.0, "snr {}", r.entries[0].snr_db);
    }

    #[test]
    fn table_renders() {
        let mut t = RangeTracker::new();
        t.observe("x", &[1.0, 2.0]);
        let tbl = t.report(1).to_table();
        assert!(tbl.contains('x') && tbl.contains("Q"));
    }
}
