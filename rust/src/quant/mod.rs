//! Quantisation tooling (§4.2): the "bit-accurate software simulator" the
//! paper uses to pick the 16-bit datapath format.
//!
//! [`range`] tracks value distributions of every tensor class flowing
//! through the float engine (inputs, gate pre-activations, cell states,
//! outputs, spectral weights) and recommends Q-formats that avoid overflow
//! while maximising fractional precision; it then *measures* the resulting
//! accuracy of the fixed-point engine against the float engine, which is
//! how we validate the paper's "16-bit fixed point is accurate enough"
//! claim without TIMIT.

pub mod range;

pub use range::{FormatReport, RangeTracker};
