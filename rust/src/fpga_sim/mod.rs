//! Cycle-approximate discrete-event simulation of the coarse-grained
//! pipeline (§4.3, §4.5, Fig 7).
//!
//! The analytical Eq 8–9 model predicts steady-state throughput; this
//! simulator *executes* the schedule frame by frame — stages connected by
//! double buffers, each stage busy for its Eq 9 cycle count, a stage
//! starting frame `f` only once (a) the upstream double buffer holds
//! frame `f` and (b) its own previous frame `f−1` has drained. It reports
//! per-frame latency, steady-state initiation interval, and per-stage
//! busy/idle occupancy, and is the cross-check that the analytical model
//! and the scheduling actually agree (a classic source of silent error in
//! accelerator papers).

use crate::schedule::algorithm1::Schedule;

/// Result of simulating `n_frames` through the pipeline.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub n_frames: usize,
    /// Steady-state initiation interval in cycles (measured between the
    /// completions of the last two frames).
    pub ii_cycles: u64,
    /// Cycle at which each frame left the pipeline.
    pub finish: Vec<u64>,
    /// Per-frame latency (finish − arrival), cycles.
    pub latency: Vec<u64>,
    /// Per-stage busy fraction over the whole run.
    pub occupancy: Vec<f64>,
}

impl SimReport {
    /// Mean steady-state latency over the second half of the run.
    pub fn steady_latency_cycles(&self) -> f64 {
        let half = &self.latency[self.latency.len() / 2..];
        half.iter().sum::<u64>() as f64 / half.len() as f64
    }
}

/// Simulate a replicated schedule processing `n_frames` back-to-back frames
/// (frames are available immediately — ASR batch mode, as in §6.1).
///
/// Double-buffer semantics: between stage `s−1` and `s` sits a two-slot
/// buffer; stage `s−1` may write frame `f+1` while stage `s` reads frame
/// `f`. Stage `s` starts frame `f` at
/// `max(finish_{s−1}(f), start_s(f−1) + T_s)` and occupies `T_s` cycles
/// (+ its pipeline depth `D_s` on the first fill).
pub fn simulate(sched: &Schedule, n_frames: usize) -> SimReport {
    let k = sched.stages.len();
    assert!(k > 0 && n_frames > 0);
    let t: Vec<u64> = sched.stages.iter().map(|s| s.cycles().max(1)).collect();
    let d: Vec<u64> = sched.stages.iter().map(|s| s.depth()).collect();

    // start[s][f], finish[s][f] — rolling, keep only per-frame vectors.
    let mut finish_prev_stage = vec![0u64; n_frames]; // finish of stage s-1 per frame
    let mut busy = vec![0u64; k];
    let mut finish_last = vec![0u64; n_frames];

    for s in 0..k {
        let mut start_prev_frame: u64 = 0;
        let mut finish_this = vec![0u64; n_frames];
        for f in 0..n_frames {
            let ready_input = if s == 0 { 0 } else { finish_prev_stage[f] };
            // Double buffer: can start once our previous frame vacated the
            // datapath (II spacing) and input is present.
            let start = if f == 0 {
                ready_input
            } else {
                ready_input.max(start_prev_frame + t[s])
            };
            // First frame pays the pipeline-fill depth.
            let fill = if f == 0 { d[s] } else { 0 };
            let fin = start + t[s] + fill;
            busy[s] += t[s];
            start_prev_frame = start;
            finish_this[f] = fin;
        }
        finish_prev_stage = finish_this.clone();
        finish_last = finish_this;
    }

    let total_cycles = *finish_last.last().unwrap();
    let latency: Vec<u64> = finish_last.clone(); // arrival = 0 for all (batch)
    let ii = if n_frames >= 2 {
        finish_last[n_frames - 1] - finish_last[n_frames - 2]
    } else {
        finish_last[0]
    };
    let occupancy = busy
        .iter()
        .map(|&b| b as f64 / total_cycles.max(1) as f64)
        .collect();
    SimReport {
        n_frames,
        ii_cycles: ii,
        finish: finish_last,
        latency,
        occupancy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::build_layer_graph;
    use crate::lstm::config::LstmSpec;
    use crate::perfmodel::performance::PerfModel;
    use crate::perfmodel::platform::Platform;
    use crate::schedule::algorithm1::schedule;
    use crate::schedule::replication::enumerate_replication;

    fn google_sched(k: usize) -> Schedule {
        let plat = Platform::ku060();
        let g = build_layer_graph(&LstmSpec::google(k), 0);
        enumerate_replication(schedule(&g, &plat.budget()), &plat.budget())
    }

    #[test]
    fn simulator_confirms_analytical_ii() {
        // The headline cross-check: discrete-event II == Eq 8 II.
        for k in [8usize, 16] {
            let s = google_sched(k);
            let analytical = PerfModel::new(Platform::ku060()).estimate(&s);
            let sim = simulate(&s, 64);
            assert_eq!(
                sim.ii_cycles, analytical.ii_cycles,
                "k={k}: sim {} vs model {}",
                sim.ii_cycles, analytical.ii_cycles
            );
        }
    }

    #[test]
    fn first_frame_latency_spans_all_stages() {
        let s = google_sched(8);
        let sim = simulate(&s, 8);
        let sum_t: u64 = s.stages.iter().map(|st| st.cycles() + st.depth()).sum();
        assert_eq!(sim.latency[0], sum_t, "fill latency is the full walk");
    }

    #[test]
    fn steady_state_spacing_is_bottleneck_stage() {
        let s = google_sched(8);
        let sim = simulate(&s, 32);
        let t_max = s.stages.iter().map(|st| st.cycles()).max().unwrap();
        // After fill, consecutive frames leave exactly T_max apart.
        for f in 8..32 {
            assert_eq!(sim.finish[f] - sim.finish[f - 1], t_max, "frame {f}");
        }
    }

    #[test]
    fn bottleneck_stage_fully_occupied() {
        let s = google_sched(8);
        let sim = simulate(&s, 128);
        let t: Vec<u64> = s.stages.iter().map(|st| st.cycles()).collect();
        let bottleneck = t
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i)
            .unwrap();
        assert!(
            sim.occupancy[bottleneck] > 0.95,
            "bottleneck occupancy {:.3}",
            sim.occupancy[bottleneck]
        );
        // Non-bottleneck stages idle — the §4.3 motivation for splitting
        // the single pipeline in the first place.
        for (i, &occ) in sim.occupancy.iter().enumerate() {
            if i != bottleneck {
                assert!(occ <= sim.occupancy[bottleneck] + 1e-9);
            }
        }
    }

    #[test]
    fn throughput_monotone_in_frames() {
        let s = google_sched(8);
        let sim = simulate(&s, 16);
        for w in sim.finish.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn single_frame_runs() {
        let s = google_sched(8);
        let sim = simulate(&s, 1);
        assert_eq!(sim.finish.len(), 1);
        assert!(sim.ii_cycles > 0);
    }
}
