//! The SynthTIMIT generator.
//!
//! Each utterance is a first-order Markov chain over `n_phones` classes
//! (self-loop probability tuned to TIMIT-like phone durations of ~7
//! frames), emitting `base_dim` mel-filterbank-like coefficients: a fixed
//! per-phone mean vector plus AR(1)-smoothed Gaussian noise, then the
//! energy term and Δ/ΔΔ temporal derivatives are appended — giving the
//! 3×(base+1)-dim features of the ESE/C-LSTM front-end (51+1 → 156≈153
//! nominal; we expose the exact dims the models use).
//!
//! The generator is seeded and deterministic: train/test splits never
//! overlap and every experiment records its seed.

use crate::util::prng::Xoshiro256;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub n_phones: usize,
    /// Base filterbank coefficients (51 for Google-style 153-dim features,
    /// 12 for Small-style 39-dim).
    pub base_dim: usize,
    /// Mean utterance length in frames.
    pub mean_frames: usize,
    /// Phone self-loop probability (expected duration = 1/(1−p)).
    pub self_loop: f64,
    /// Emission noise std relative to inter-phone mean distances.
    pub noise: f64,
    pub seed: u64,
}

impl SynthConfig {
    /// Matches the Google-LSTM front-end: 51 coefficients + energy, ×3
    /// derivative channels = 156 dims; models read the first 153.
    pub fn google() -> Self {
        Self {
            n_phones: 39,
            base_dim: 51,
            mean_frames: 120,
            self_loop: 0.857, // ≈7-frame phones
            noise: 0.45,
            seed: 0x7131,
        }
    }

    /// Small-LSTM front-end: 12 coefficients + energy, ×3 = 39 dims.
    pub fn small() -> Self {
        Self {
            base_dim: 12,
            ..Self::google()
        }
    }

    /// Shrunk config for unit tests.
    pub fn tiny() -> Self {
        Self {
            n_phones: 8,
            base_dim: 5,
            mean_frames: 30,
            self_loop: 0.75,
            noise: 0.3,
            seed: 42,
        }
    }

    /// Total feature dimension: (base + energy) × {static, Δ, ΔΔ}.
    pub fn feature_dim(&self) -> usize {
        (self.base_dim + 1) * 3
    }
}

/// One utterance: frames plus framewise phone labels.
#[derive(Debug, Clone)]
pub struct Utterance {
    pub frames: Vec<Vec<f32>>,
    pub labels: Vec<usize>,
}

impl Utterance {
    /// Reference phone sequence (labels with repeats collapsed).
    pub fn phone_seq(&self) -> Vec<usize> {
        super::per::collapse(&self.labels)
    }
}

/// The dataset generator.
pub struct SynthTimit {
    pub cfg: SynthConfig,
    /// Per-phone emission means (n_phones × base_dim).
    means: Vec<Vec<f64>>,
    /// Phone transition preferences (sparse bigram structure).
    trans: Vec<Vec<f64>>,
}

impl SynthTimit {
    pub fn new(cfg: SynthConfig) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
        // Per-phone formant-like means: smooth bumps at phone-dependent
        // positions so nearby phones are genuinely confusable (PER is not
        // trivially 0, like real acoustics).
        let means: Vec<Vec<f64>> = (0..cfg.n_phones)
            .map(|p| {
                let centre = (p as f64 + 0.5) / cfg.n_phones as f64;
                let width = 0.08 + 0.04 * rng.next_f64();
                let amp = 1.0 + 0.5 * rng.next_f64();
                (0..cfg.base_dim)
                    .map(|d| {
                        let x = d as f64 / cfg.base_dim as f64;
                        let bump = (-((x - centre) * (x - centre)) / (2.0 * width * width)).exp();
                        amp * bump + 0.15 * rng.normal()
                    })
                    .collect()
            })
            .collect();
        // Bigram structure: each phone prefers a handful of successors.
        let trans: Vec<Vec<f64>> = (0..cfg.n_phones)
            .map(|_| {
                let mut row: Vec<f64> = (0..cfg.n_phones).map(|_| 0.05 + rng.next_f64()).collect();
                // Boost 4 preferred successors.
                for _ in 0..4 {
                    let j = rng.index(cfg.n_phones);
                    row[j] += 3.0;
                }
                row
            })
            .collect();
        Self { cfg, means, trans }
    }

    /// Generate utterance number `idx` of split `split_seed` (deterministic
    /// per (idx, split)).
    pub fn utterance(&self, split_seed: u64, idx: u64) -> Utterance {
        // Seed hashing mixes mod 2^64 on purpose — exempt from the
        // crate-wide wrapping-op ban.
        #[allow(clippy::disallowed_methods)]
        let seed = self.cfg.seed ^ split_seed.wrapping_mul(0x9E37_79B9).wrapping_add(idx);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let n_frames = (self.cfg.mean_frames as f64 * rng.uniform(0.6, 1.4)) as usize;
        let n_frames = n_frames.max(8);
        let d = self.cfg.base_dim;

        let mut labels = Vec::with_capacity(n_frames);
        let mut phone = rng.index(self.cfg.n_phones);
        // Static channel with AR(1) smoothing.
        let mut stat = vec![0.0f64; d + 1];
        let mut raw: Vec<Vec<f64>> = Vec::with_capacity(n_frames);
        for _ in 0..n_frames {
            if rng.next_f64() > self.cfg.self_loop {
                phone = rng.weighted_index(&self.trans[phone]);
            }
            labels.push(phone);
            let mean = &self.means[phone];
            let mut frame = vec![0.0f64; d + 1];
            let mut energy = 0.0;
            for i in 0..d {
                let target = mean[i] + self.cfg.noise * rng.normal();
                // AR(1): frames correlate in time like real speech.
                stat[i] = 0.6 * stat[i] + 0.4 * target;
                frame[i] = stat[i];
                energy += stat[i] * stat[i];
            }
            frame[d] = (energy / d as f64).sqrt(); // energy channel
            raw.push(frame);
        }

        // Δ and ΔΔ channels (central differences, edge-clamped).
        let deriv = |xs: &[Vec<f64>]| -> Vec<Vec<f64>> {
            let n = xs.len();
            (0..n)
                .map(|t| {
                    let prev = &xs[t.saturating_sub(1)];
                    let next = &xs[(t + 1).min(n - 1)];
                    prev.iter().zip(next).map(|(a, b)| (b - a) / 2.0).collect()
                })
                .collect()
        };
        let d1 = deriv(&raw);
        let d2 = deriv(&d1);

        let frames: Vec<Vec<f32>> = (0..n_frames)
            .map(|t| {
                raw[t]
                    .iter()
                    .chain(d1[t].iter())
                    .chain(d2[t].iter())
                    .map(|&v| v as f32)
                    .collect()
            })
            .collect();
        Utterance { frames, labels }
    }

    /// A batch of utterances.
    pub fn batch(&self, split_seed: u64, n: usize) -> Vec<Utterance> {
        (0..n as u64).map(|i| self.utterance(split_seed, i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let gen = SynthTimit::new(SynthConfig::tiny());
        let u1 = gen.utterance(1, 0);
        let u2 = gen.utterance(1, 0);
        assert_eq!(u1.labels, u2.labels);
        assert_eq!(u1.frames.len(), u1.labels.len());
        assert_eq!(u1.frames[0].len(), SynthConfig::tiny().feature_dim());
        // Different idx ⇒ different content.
        let u3 = gen.utterance(1, 1);
        assert_ne!(u1.labels, u3.labels);
    }

    #[test]
    fn google_config_feature_dim() {
        assert_eq!(SynthConfig::google().feature_dim(), 156);
        assert_eq!(SynthConfig::small().feature_dim(), 39);
    }

    #[test]
    fn phone_durations_realistic() {
        let gen = SynthTimit::new(SynthConfig::google());
        let mut total_runs = 0usize;
        let mut total_frames = 0usize;
        for i in 0..10 {
            let u = gen.utterance(2, i);
            total_runs += u.phone_seq().len();
            total_frames += u.labels.len();
        }
        let mean_dur = total_frames as f64 / total_runs as f64;
        assert!(
            (3.0..=14.0).contains(&mean_dur),
            "mean phone duration {mean_dur} frames"
        );
    }

    #[test]
    fn features_are_class_informative() {
        // A nearest-mean classifier on static channels must beat chance by
        // a lot — otherwise PER trends would be meaningless noise.
        let cfg = SynthConfig::tiny();
        let gen = SynthTimit::new(cfg.clone());
        // Estimate class means from one split.
        let mut sums = vec![vec![0.0f64; cfg.base_dim]; cfg.n_phones];
        let mut counts = vec![0usize; cfg.n_phones];
        for i in 0..20 {
            let u = gen.utterance(3, i);
            for (f, &l) in u.frames.iter().zip(&u.labels) {
                for d in 0..cfg.base_dim {
                    sums[l][d] += f[d] as f64;
                }
                counts[l] += 1;
            }
        }
        for (s, &c) in sums.iter_mut().zip(&counts) {
            for v in s.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        // Classify a fresh split.
        let mut correct = 0usize;
        let mut total = 0usize;
        for i in 0..10 {
            let u = gen.utterance(4, i);
            for (f, &l) in u.frames.iter().zip(&u.labels) {
                let pred = (0..cfg.n_phones)
                    .min_by(|&a, &b| {
                        let da: f64 = (0..cfg.base_dim)
                            .map(|d| (f[d] as f64 - sums[a][d]).powi(2))
                            .sum();
                        let db: f64 = (0..cfg.base_dim)
                            .map(|d| (f[d] as f64 - sums[b][d]).powi(2))
                            .sum();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                correct += (pred == l) as usize;
                total += 1;
            }
        }
        let acc = correct as f64 / total as f64;
        let chance = 1.0 / cfg.n_phones as f64;
        assert!(
            acc > 3.0 * chance,
            "nearest-mean accuracy {acc:.3} barely beats chance {chance:.3}"
        );
        // ...but not trivially separable either (noise + confusable means).
        assert!(acc < 0.999, "task too easy: {acc}");
    }

    #[test]
    fn derivative_channels_encode_dynamics() {
        let gen = SynthTimit::new(SynthConfig::tiny());
        let u = gen.utterance(5, 0);
        let d = SynthConfig::tiny().base_dim + 1;
        // Δ channel of a changing signal must be non-zero somewhere.
        let delta_energy: f32 = u
            .frames
            .iter()
            .map(|f| f[d..2 * d].iter().map(|v| v.abs()).sum::<f32>())
            .sum();
        assert!(delta_energy > 0.1);
    }
}
