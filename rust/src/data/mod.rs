//! SynthTIMIT — the synthetic stand-in for the TIMIT corpus — and the PER
//! metric (§3.3, §6; DESIGN.md §2 documents the substitution).
//!
//! - [`synth`] — an HMM-style generator over the 39-phone folded TIMIT
//!   inventory emitting 153-dim (Google) or 39-dim (Small) filterbank-like
//!   feature frames: per-phone Gaussian emission means, temporal smoothing,
//!   and Δ/ΔΔ derivative channels, matching the front-end both ESE and
//!   C-LSTM used (51/12 mel coefficients + energy, with first and second
//!   temporal derivatives).
//! - [`per`] — Phone Error Rate: collapse framewise predictions to a phone
//!   sequence, then Levenshtein distance against the reference sequence
//!   over reference length — the metric of Tables 1 and 3.

pub mod per;
pub mod synth;

pub use per::{collapse, edit_distance, phone_error_rate};
pub use synth::{SynthConfig, SynthTimit, Utterance};
