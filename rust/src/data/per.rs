//! Phone Error Rate (PER) — the accuracy metric of Tables 1 and 3.
//!
//! Framewise predictions are collapsed to a phone sequence (consecutive
//! repeats merged — the standard framewise-decoder convention), then PER =
//! Levenshtein(hyp, ref) / len(ref), summed over a corpus.

/// Merge consecutive repeats: `[a a b b b a] → [a b a]`.
pub fn collapse(labels: &[usize]) -> Vec<usize> {
    let mut out = Vec::new();
    for &l in labels {
        if out.last() != Some(&l) {
            out.push(l);
        }
    }
    out
}

/// Levenshtein distance (substitution/insertion/deletion all cost 1).
pub fn edit_distance(a: &[usize], b: &[usize]) -> usize {
    let (n, m) = (a.len(), b.len());
    if n == 0 {
        return m;
    }
    if m == 0 {
        return n;
    }
    let mut prev: Vec<usize> = (0..=m).collect();
    let mut cur = vec![0usize; m + 1];
    for i in 1..=n {
        cur[0] = i;
        for j in 1..=m {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[m]
}

/// Corpus PER in percent: Σ edit distances / Σ reference lengths × 100,
/// with framewise hypotheses collapsed first.
pub fn phone_error_rate(hyps_framewise: &[Vec<usize>], refs: &[Vec<usize>]) -> f64 {
    assert_eq!(hyps_framewise.len(), refs.len());
    let mut errs = 0usize;
    let mut total = 0usize;
    for (h, r) in hyps_framewise.iter().zip(refs) {
        let hc = collapse(h);
        errs += edit_distance(&hc, r);
        total += r.len();
    }
    100.0 * errs as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;
    use crate::util::testing::{forall, gen, no_shrink, Config};

    #[test]
    fn collapse_basics() {
        assert_eq!(collapse(&[1, 1, 2, 2, 2, 1]), vec![1, 2, 1]);
        assert_eq!(collapse(&[]), Vec::<usize>::new());
        assert_eq!(collapse(&[3]), vec![3]);
    }

    #[test]
    fn edit_distance_known_cases() {
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 3]), 1); // deletion
        assert_eq!(edit_distance(&[1, 3], &[1, 2, 3]), 1); // insertion
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 9, 3]), 1); // substitution
        assert_eq!(edit_distance(&[], &[1, 2]), 2);
    }

    #[test]
    fn per_zero_for_perfect_and_100_band_for_garbage() {
        let refs = vec![vec![1, 2, 3], vec![4, 5]];
        let hyps = vec![vec![1, 1, 2, 3, 3], vec![4, 4, 5]];
        assert_eq!(phone_error_rate(&hyps, &refs), 0.0);
        let garbage = vec![vec![9, 9, 9], vec![9]];
        let per = phone_error_rate(&garbage, &refs);
        assert!(per >= 100.0 * 4.0 / 5.0, "{per}");
    }

    #[test]
    fn property_metric_axioms() {
        forall(
            Config::default().cases(80),
            |rng| {
                let a: Vec<usize> = (0..gen::usize_in(rng, 0..=12))
                    .map(|_| rng.index(5))
                    .collect();
                let b: Vec<usize> = (0..gen::usize_in(rng, 0..=12))
                    .map(|_| rng.index(5))
                    .collect();
                let c: Vec<usize> = (0..gen::usize_in(rng, 0..=12))
                    .map(|_| rng.index(5))
                    .collect();
                (a, b, c)
            },
            no_shrink,
            |(a, b, c)| {
                // Identity, symmetry, triangle inequality.
                if edit_distance(a, a) != 0 {
                    return Err("d(a,a) != 0".into());
                }
                if edit_distance(a, b) != edit_distance(b, a) {
                    return Err("asymmetric".into());
                }
                let (ab, bc, ac) = (
                    edit_distance(a, b),
                    edit_distance(b, c),
                    edit_distance(a, c),
                );
                if ac > ab + bc {
                    return Err(format!("triangle violated: {ac} > {ab}+{bc}"));
                }
                // Bounded by max length.
                if ab > a.len().max(b.len()) {
                    return Err("distance exceeds max length".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_collapse_idempotent_and_no_repeats() {
        forall(
            Config::default().cases(60),
            |rng| {
                (0..gen::usize_in(rng, 0..=40))
                    .map(|_| rng.index(4))
                    .collect::<Vec<usize>>()
            },
            no_shrink,
            |xs| {
                let c = collapse(xs);
                if c.windows(2).any(|w| w[0] == w[1]) {
                    return Err("repeats survive".into());
                }
                if collapse(&c) != c {
                    return Err("not idempotent".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn per_monotone_in_corruption() {
        // Corrupting more frames can only raise (or keep) PER.
        let mut rng = Xoshiro256::seed_from_u64(7);
        let labels: Vec<usize> = (0..60).map(|i| (i / 6) % 5).collect();
        let refs = vec![collapse(&labels)];
        let mut prev_per = 0.0;
        for corrupt in [0usize, 5, 15, 30] {
            let mut hyp = labels.clone();
            for _ in 0..corrupt {
                let idx = rng.index(hyp.len());
                hyp[idx] = (hyp[idx] + 1 + rng.index(4)) % 5;
            }
            let per = phone_error_rate(&[hyp], &refs);
            assert!(
                per + 1e-9 >= prev_per * 0.5,
                "PER should broadly rise with corruption"
            );
            prev_per = per;
        }
        assert!(prev_per > 0.0);
    }
}
