//! Operator dependency graphs (§4.3, Fig 6a).
//!
//! The C-LSTM synthesis flow starts by transforming the LSTM algorithm
//! specification (the Eq 1 equations) into a directed acyclic dependency
//! graph whose nodes are *primitive operators* — circulant convolution,
//! element-wise add/multiply, sigmoid, tanh — and whose edges are data
//! dependencies. Feedback edges (`c_t`, `y_t` into the next time step) are
//! deliberately removed; the double-buffer mechanism of the coarse-grained
//! pipeline carries them (§4.3).
//!
//! [`op`] defines the operator vocabulary with per-operator workloads
//! `Q(v)` and arithmetic complexities `W(v)` (Fig 5); [`builder`] generates
//! the graph for any [`LstmSpec`](crate::lstm::LstmSpec); [`dag`] is the
//! graph structure itself with topological utilities.

pub mod builder;
pub mod dag;
pub mod op;

pub use builder::build_layer_graph;
pub use dag::OpGraph;
pub use op::{OpKind, OpNode};
