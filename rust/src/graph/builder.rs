//! LSTM equations → operator dependency graph (§4.3, Fig 6a).
//!
//! Builds the per-layer operator graph from an [`LstmSpec`], following
//! Eq 1a–1g with the fused `W_{*(xr)}[x_t, y_{t-1}]` mat-vecs. Feedback
//! edges (`c_{t-1}` into the gate peepholes and `y_{t-1}` into the fused
//! convolutions) are *not* edges — they are carried between time steps by
//! the double-buffer mechanism, which is what makes the graph acyclic.

use super::dag::OpGraph;
use super::op::OpKind;
use crate::lstm::config::LstmSpec;

/// Build the operator graph of one direction of layer `l`.
///
/// Node inventory for the full Google LSTM cell (Fig 6a): four fused gate
/// convolutions, the element-wise cluster (peephole multiplies, bias adds,
/// activations, cell update, output gating), and the projection
/// convolution; 18 operators total with peepholes + projection, fewer for
/// the Small LSTM.
pub fn build_layer_graph(spec: &LstmSpec, l: usize) -> OpGraph {
    let mut g = OpGraph::new();
    let h = spec.hidden_dim;
    let hp = spec.pad(h);
    let fused = spec.fused_in_dim(l);
    let (p, q, k) = (hp / spec.k, fused / spec.k, spec.k);

    // --- Stage-1 material: the four fused gate convolutions (Eq 1a–1c, 1e).
    let conv_i = g.add(OpKind::CirConv, "conv_Wi(xr)", hp, (p, q, k));
    let conv_f = g.add(OpKind::CirConv, "conv_Wf(xr)", hp, (p, q, k));
    let conv_g = g.add(OpKind::CirConv, "conv_Wg(xr)", hp, (p, q, k));
    let conv_o = g.add(OpKind::CirConv, "conv_Wo(xr)", hp, (p, q, k));

    // --- Element-wise cluster.
    // Gate i: (+ peephole·c_{t-1}) + bias → σ.
    let (add_i, sig_i) = if spec.peephole {
        let peep_i = g.add(OpKind::EwMul, "mul_Wic_c", h, (0, 0, 0));
        let add_i = g.add(OpKind::EwAdd, "add_i", h, (0, 0, 0));
        g.edge(conv_i, add_i);
        g.edge(peep_i, add_i);
        let sig_i = g.add(OpKind::Sigmoid, "sigmoid_i", h, (0, 0, 0));
        g.edge(add_i, sig_i);
        (add_i, sig_i)
    } else {
        let add_i = g.add(OpKind::EwAdd, "add_i", h, (0, 0, 0));
        g.edge(conv_i, add_i);
        let sig_i = g.add(OpKind::Sigmoid, "sigmoid_i", h, (0, 0, 0));
        g.edge(add_i, sig_i);
        (add_i, sig_i)
    };
    let _ = add_i;

    // Gate f.
    let sig_f = if spec.peephole {
        let peep_f = g.add(OpKind::EwMul, "mul_Wfc_c", h, (0, 0, 0));
        let add_f = g.add(OpKind::EwAdd, "add_f", h, (0, 0, 0));
        g.edge(conv_f, add_f);
        g.edge(peep_f, add_f);
        let s = g.add(OpKind::Sigmoid, "sigmoid_f", h, (0, 0, 0));
        g.edge(add_f, s);
        s
    } else {
        let add_f = g.add(OpKind::EwAdd, "add_f", h, (0, 0, 0));
        g.edge(conv_f, add_f);
        let s = g.add(OpKind::Sigmoid, "sigmoid_f", h, (0, 0, 0));
        g.edge(add_f, s);
        s
    };

    // Candidate g (Eq 1c): bias add → tanh.
    let add_g = g.add(OpKind::EwAdd, "add_g", h, (0, 0, 0));
    g.edge(conv_g, add_g);
    let tanh_g = g.add(OpKind::Tanh, "tanh_g", h, (0, 0, 0));
    g.edge(add_g, tanh_g);

    // Cell update (Eq 1d): f⊙c_{t-1} + g⊙i.
    let mul_fc = g.add(OpKind::EwMul, "mul_f_c", h, (0, 0, 0));
    g.edge(sig_f, mul_fc);
    let mul_gi = g.add(OpKind::EwMul, "mul_g_i", h, (0, 0, 0));
    g.edge(tanh_g, mul_gi);
    g.edge(sig_i, mul_gi);
    let add_c = g.add(OpKind::EwAdd, "add_c", h, (0, 0, 0));
    g.edge(mul_fc, add_c);
    g.edge(mul_gi, add_c);

    // Gate o (Eq 1e): peephole reads c_t (a real forward edge!).
    let sig_o = if spec.peephole {
        let peep_o = g.add(OpKind::EwMul, "mul_Woc_ct", h, (0, 0, 0));
        g.edge(add_c, peep_o);
        let add_o = g.add(OpKind::EwAdd, "add_o", h, (0, 0, 0));
        g.edge(conv_o, add_o);
        g.edge(peep_o, add_o);
        let s = g.add(OpKind::Sigmoid, "sigmoid_o", h, (0, 0, 0));
        g.edge(add_o, s);
        s
    } else {
        let add_o = g.add(OpKind::EwAdd, "add_o", h, (0, 0, 0));
        g.edge(conv_o, add_o);
        let s = g.add(OpKind::Sigmoid, "sigmoid_o", h, (0, 0, 0));
        g.edge(add_o, s);
        s
    };

    // Output (Eq 1f): m = o ⊙ h(c_t).
    let tanh_c = g.add(OpKind::Tanh, "tanh_ct", h, (0, 0, 0));
    g.edge(add_c, tanh_c);
    let mul_m = g.add(OpKind::EwMul, "mul_o_hc", h, (0, 0, 0));
    g.edge(sig_o, mul_m);
    g.edge(tanh_c, mul_m);

    // Projection (Eq 1g) — the Stage-3 convolution of Fig 6b.
    if let Some(pd) = spec.proj_dim {
        let pp = spec.pad(pd) / k;
        let conv_y = g.add(OpKind::CirConv, "conv_Wym", spec.pad(pd), (pp, hp / k, k));
        g.edge(mul_m, conv_y);
    }

    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;

    #[test]
    fn google_graph_matches_fig6a_inventory() {
        let g = build_layer_graph(&LstmSpec::google(8), 0);
        assert!(g.is_acyclic(), "feedback edges must be excluded");
        let convs = g
            .nodes
            .iter()
            .filter(|n| n.kind == OpKind::CirConv)
            .count();
        // 4 fused gates + 1 projection.
        assert_eq!(convs, 5);
        // Full inventory: 5 convs + 6 ⊙ (3 peepholes, f·c, g·i, o·h(c)) +
        // 5 adds (i, f, g, c, o) + 3 sigmoids + 2 tanhs = 21.
        assert_eq!(g.len(), 21);
    }

    #[test]
    fn small_graph_has_no_peephole_no_projection() {
        let g = build_layer_graph(&LstmSpec::small(8), 0);
        assert!(g.is_acyclic());
        let convs = g
            .nodes
            .iter()
            .filter(|n| n.kind == OpKind::CirConv)
            .count();
        assert_eq!(convs, 4);
        assert!(!g.nodes.iter().any(|n| n.name.contains("Wic")));
        assert!(!g.nodes.iter().any(|n| n.name.contains("Wym")));
    }

    #[test]
    fn projection_is_the_unique_sink() {
        let g = build_layer_graph(&LstmSpec::google(8), 0);
        let sinks: Vec<_> = (0..g.len()).filter(|&v| g.succs[v].is_empty()).collect();
        assert_eq!(sinks.len(), 1);
        assert_eq!(g.nodes[sinks[0]].name, "conv_Wym");
    }

    #[test]
    fn gate_convs_have_highest_priority() {
        // Eq 7: the longest chains start at the gate convolutions, so
        // Algorithm 1 visits them first — which is what produces the
        // Fig 6b stage split.
        let g = build_layer_graph(&LstmSpec::google(8), 0);
        let order = g.by_priority();
        let first_four: Vec<_> = order[..4]
            .iter()
            .map(|&v| g.nodes[v].kind)
            .collect();
        assert!(
            first_four.iter().all(|k| *k == OpKind::CirConv),
            "first four by priority should be the gate convs, got {first_four:?}"
        );
    }

    #[test]
    fn output_peephole_depends_on_cell_update() {
        let g = build_layer_graph(&LstmSpec::google(8), 0);
        let add_c = g.nodes.iter().find(|n| n.name == "add_c").unwrap().id;
        let peep_o = g.nodes.iter().find(|n| n.name == "mul_Woc_ct").unwrap().id;
        assert!(g.succs[add_c].contains(&peep_o), "Eq 1e reads c_t");
    }

    #[test]
    fn layer2_dimensions_differ() {
        let spec = LstmSpec::google(8);
        let g0 = build_layer_graph(&spec, 0);
        let g1 = build_layer_graph(&spec, 1);
        let q0 = g0.nodes[0].pqk.1;
        let q1 = g1.nodes[0].pqk.1;
        assert_eq!(q0, (160 + 512) / 8);
        assert_eq!(q1, (512 + 512) / 8);
    }
}
