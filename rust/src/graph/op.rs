//! The primitive operator vocabulary (§4.3, Fig 5; §5.2).
//!
//! "We define hyperbolic tangent tanh, sigmoid σ, element-wise vector
//! addition, element-wise vector multiplication, and circulant convolution
//! as primitive operators."
//!
//! Each node carries its workload `Q(v)` — the per-frame cycle count at
//! parallelism 1 — and its arithmetic complexity `W(v)` used by the Eq 7
//! priority function and the Fig 5 complexity breakdown.

/// The five primitive operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// FFT-based circulant convolution of a `p×q`-block matrix, block `k`.
    CirConv,
    /// Element-wise vector addition.
    EwAdd,
    /// Element-wise vector multiplication (⊙, incl. peepholes).
    EwMul,
    /// Sigmoid activation (22-segment PWL in hardware).
    Sigmoid,
    /// Tanh activation (22-segment PWL in hardware).
    Tanh,
}

impl OpKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            OpKind::CirConv => "cirConv",
            OpKind::EwAdd => "ewAdd",
            OpKind::EwMul => "ewMul",
            OpKind::Sigmoid => "sigmoid",
            OpKind::Tanh => "tanh",
        }
    }
}

/// A node in the operator graph.
#[derive(Debug, Clone)]
pub struct OpNode {
    pub id: usize,
    pub kind: OpKind,
    /// Human-readable role, e.g. `"conv_Wi"`, `"mul_f_c"`.
    pub name: String,
    /// Output vector length (elements per frame).
    pub out_len: usize,
    /// For CirConv: (p, q, k); element-wise ops leave this zeroed.
    pub pqk: (usize, usize, usize),
}

impl OpNode {
    /// Per-frame workload `Q(v)` in elementary cycles at parallelism 1
    /// (Eq 9). A circulant-conv unit streams one packed spectrum bin per
    /// cycle through the ⊙-accumulate datapath; the shared input DFTs and
    /// the per-row IDFTs are pipelined into the same stream (§4.5), so the
    /// dominant term is `p·q·(k/2 + 1)`. An element-wise unit handles one
    /// element per cycle.
    pub fn workload(&self) -> u64 {
        match self.kind {
            OpKind::CirConv => {
                let (p, q, k) = self.pqk;
                (p * q * (k / 2 + 1)) as u64
            }
            _ => self.out_len as u64,
        }
    }

    /// Arithmetic complexity `W(v)` — real multiply-equivalents per frame,
    /// the Fig 5 quantity and the Eq 7 priority weight.
    pub fn complexity(&self) -> u64 {
        match self.kind {
            OpKind::CirConv => {
                let (p, q, k) = self.pqk;
                let kf = k as f64;
                // Packed ⊙ (≈2k real mults per block) + amortised
                // transforms (2k·log2 k per length-k FFT, (p+q) of them).
                let ew = (p * q) as f64 * 2.0 * kf;
                let tr = (p + q) as f64 * 2.0 * kf * kf.log2().max(1.0);
                (ew + tr) as u64
            }
            // One op per element; activations count the PWL multiply.
            _ => self.out_len as u64,
        }
    }
}

/// The Fig 5 series: normalised complexity of the five primitive operators
/// for a given model layer (values relative to the cheapest).
pub fn fig5_series(hidden: usize, fused_in: usize, k: usize) -> Vec<(OpKind, f64)> {
    let conv = OpNode {
        id: 0,
        kind: OpKind::CirConv,
        name: "conv".into(),
        out_len: hidden,
        pqk: (hidden / k, fused_in / k, k),
    };
    let ew = |kind: OpKind| OpNode {
        id: 0,
        kind,
        name: "ew".into(),
        out_len: hidden,
        pqk: (0, 0, 0),
    };
    let raw = vec![
        (OpKind::CirConv, conv.complexity() as f64),
        (OpKind::EwAdd, ew(OpKind::EwAdd).complexity() as f64),
        (OpKind::EwMul, ew(OpKind::EwMul).complexity() as f64),
        (OpKind::Sigmoid, ew(OpKind::Sigmoid).complexity() as f64),
        (OpKind::Tanh, ew(OpKind::Tanh).complexity() as f64),
    ];
    let min = raw.iter().map(|(_, v)| *v).fold(f64::INFINITY, f64::min);
    raw.into_iter().map(|(k, v)| (k, v / min)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_conv_dominates_by_about_128x() {
        // §4.3: "The computational complexity gap between the circulant
        // convolution operator and element-wise multiply operator ⊙ is as
        // large as 128 times" (Google LSTM, k=8: fused 672-dim input,
        // 1024 hidden → 2·fused/k·... ≈ 2q = 168; the paper's 128 counts
        // per-element work ratio ≈ 2q·(k/2+1)/k ≈ 105–170 depending on
        // accounting). Assert the gap is in that band.
        let series = fig5_series(1024, 672, 8);
        let conv = series
            .iter()
            .find(|(k, _)| *k == OpKind::CirConv)
            .unwrap()
            .1;
        let mul = series.iter().find(|(k, _)| *k == OpKind::EwMul).unwrap().1;
        let gap = conv / mul;
        assert!(
            (60.0..=260.0).contains(&gap),
            "conv/⊙ complexity gap {gap} outside the Fig 5 band"
        );
    }

    #[test]
    fn elementwise_ops_equal_complexity() {
        let s = fig5_series(512, 512, 8);
        let add = s.iter().find(|(k, _)| *k == OpKind::EwAdd).unwrap().1;
        let mul = s.iter().find(|(k, _)| *k == OpKind::EwMul).unwrap().1;
        assert_eq!(add, mul);
        assert_eq!(add, 1.0, "normalised to cheapest");
    }

    #[test]
    fn workload_scales_with_blocks() {
        let mk = |p, q, k| OpNode {
            id: 0,
            kind: OpKind::CirConv,
            name: "c".into(),
            out_len: p * k,
            pqk: (p, q, k),
        };
        assert_eq!(mk(128, 84, 8).workload(), 128 * 84 * 5);
        assert_eq!(mk(64, 42, 16).workload(), 64 * 42 * 9);
        // Halving k (same matrix) increases workload: less compression.
        assert!(mk(128, 84, 8).workload() > mk(64, 42, 16).workload());
    }
}
