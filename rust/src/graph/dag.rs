//! The operator dependency DAG with topological utilities.

use super::op::{OpKind, OpNode};
use std::collections::VecDeque;

/// A directed acyclic operator graph. Node ids are dense indices into
/// `nodes`; edges are stored as adjacency lists both ways.
#[derive(Debug, Clone, Default)]
pub struct OpGraph {
    pub nodes: Vec<OpNode>,
    /// `succs[v]` — ids of operators consuming v's output.
    pub succs: Vec<Vec<usize>>,
    /// `preds[v]` — ids of operators producing v's inputs.
    pub preds: Vec<Vec<usize>>,
}

impl OpGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a node; returns its id.
    pub fn add(&mut self, kind: OpKind, name: &str, out_len: usize, pqk: (usize, usize, usize)) -> usize {
        let id = self.nodes.len();
        self.nodes.push(OpNode {
            id,
            kind,
            name: name.to_string(),
            out_len,
            pqk,
        });
        self.succs.push(Vec::new());
        self.preds.push(Vec::new());
        id
    }

    /// Add a dependency edge `from → to`.
    pub fn edge(&mut self, from: usize, to: usize) {
        assert!(from < self.nodes.len() && to < self.nodes.len());
        assert_ne!(from, to, "self-loop would make the graph cyclic");
        if !self.succs[from].contains(&to) {
            self.succs[from].push(to);
            self.preds[to].push(from);
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Kahn topological order; `None` if the graph has a cycle.
    pub fn topo_order(&self) -> Option<Vec<usize>> {
        let mut indeg: Vec<usize> = self.preds.iter().map(Vec::len).collect();
        let mut queue: VecDeque<usize> = (0..self.len()).filter(|&v| indeg[v] == 0).collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for &s in &self.succs[v] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push_back(s);
                }
            }
        }
        (order.len() == self.len()).then_some(order)
    }

    /// True if the graph is acyclic.
    pub fn is_acyclic(&self) -> bool {
        self.topo_order().is_some()
    }

    /// Eq 7 priorities: `P(v) = W(v) + max_{s ∈ succ(v)} P(s)` (longest
    /// weighted path to a sink). Computed in reverse topological order.
    pub fn priorities(&self) -> Vec<u64> {
        let order = self.topo_order().expect("operator graph must be acyclic");
        let mut p = vec![0u64; self.len()];
        for &v in order.iter().rev() {
            let best_succ = self.succs[v].iter().map(|&s| p[s]).max().unwrap_or(0);
            p[v] = self.nodes[v].complexity() + best_succ;
        }
        p
    }

    /// Node ids sorted by decreasing priority (Algorithm 1's visit order);
    /// ties broken by id for determinism.
    pub fn by_priority(&self) -> Vec<usize> {
        let p = self.priorities();
        let mut ids: Vec<usize> = (0..self.len()).collect();
        ids.sort_by_key(|&v| (std::cmp::Reverse(p[v]), v));
        ids
    }

    /// Render as Graphviz dot (squares = cirConv, circles = element-wise,
    /// matching the Fig 6 legend).
    pub fn to_dot(&self) -> String {
        let mut s = String::from("digraph lstm {\n  rankdir=TB;\n");
        for n in &self.nodes {
            let shape = if n.kind == OpKind::CirConv {
                "box"
            } else {
                "ellipse"
            };
            s.push_str(&format!(
                "  n{} [label=\"{}\" shape={}];\n",
                n.id, n.name, shape
            ));
        }
        for (v, ss) in self.succs.iter().enumerate() {
            for &t in ss {
                s.push_str(&format!("  n{v} -> n{t};\n"));
            }
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> OpGraph {
        // a → b, a → c, b → d, c → d
        let mut g = OpGraph::new();
        let a = g.add(OpKind::CirConv, "a", 64, (8, 8, 8));
        let b = g.add(OpKind::EwAdd, "b", 64, (0, 0, 0));
        let c = g.add(OpKind::EwMul, "c", 64, (0, 0, 0));
        let d = g.add(OpKind::Sigmoid, "d", 64, (0, 0, 0));
        g.edge(a, b);
        g.edge(a, c);
        g.edge(b, d);
        g.edge(c, d);
        g
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; g.len()];
            for (i, &v) in order.iter().enumerate() {
                p[v] = i;
            }
            p
        };
        for (v, ss) in g.succs.iter().enumerate() {
            for &t in ss {
                assert!(pos[v] < pos[t], "{v} must precede {t}");
            }
        }
    }

    #[test]
    fn cycle_detected() {
        let mut g = diamond();
        g.edge(3, 0); // close the loop
        assert!(!g.is_acyclic());
    }

    #[test]
    fn priorities_are_topologically_monotone() {
        // Eq 7: a predecessor's priority strictly exceeds each successor's.
        let g = diamond();
        let p = g.priorities();
        for (v, ss) in g.succs.iter().enumerate() {
            for &t in ss {
                assert!(p[v] > p[t], "P({v})={} !> P({t})={}", p[v], p[t]);
            }
        }
    }

    #[test]
    fn priority_order_schedules_preds_before_succs_along_chains() {
        let g = diamond();
        let order = g.by_priority();
        let pos_a = order.iter().position(|&v| v == 0).unwrap();
        let pos_d = order.iter().position(|&v| v == 3).unwrap();
        assert!(pos_a < pos_d);
    }

    #[test]
    fn sink_priority_is_own_weight() {
        let g = diamond();
        let p = g.priorities();
        assert_eq!(p[3], g.nodes[3].complexity());
    }

    #[test]
    fn dot_renders_shapes() {
        let dot = diamond().to_dot();
        assert!(dot.contains("shape=box") && dot.contains("shape=ellipse"));
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn rejects_self_loop() {
        let mut g = diamond();
        g.edge(1, 1);
    }
}
