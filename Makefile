# C-LSTM top-level targets. The Rust crate is self-sufficient (native
# serving backend); the artifact targets need the layer-1/2 Python
# environment (jax, numpy) and are optional.

.PHONY: build test bench serve-bench bench-fxp-stage1 bench-simd bench-overload serve-fxp serve-stack serve-overload serve-chaos serve-trace verify-datapath artifacts table1-per

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench:
	cd rust && CLSTM_BENCH_FAST=1 cargo bench

# Replica-scaling serving benchmark (engine lanes 1/2/4, CI-sized budgets).
serve-bench:
	cd rust && CLSTM_BENCH_FAST=1 cargo bench --bench bench_pipeline

# Fused fxp stage-1 benchmark: four-plans vs stacked frames/s (the PR-5
# before/after), the native stage-1 reference, and the serve p99 under the
# event-driven scheduler wakeup — (re)writes BENCH_5.json at the repo root.
bench-fxp-stage1:
	cd rust && CLSTM_BENCH_FAST=1 cargo bench --bench bench_pipeline
	test -s BENCH_5.json && grep -q "stage1_speedup" BENCH_5.json
	grep -q '"source": "native:' BENCH_5.json

# Scalar-vs-SIMD spectral kernel split (PR 7): fxp fused stage-1 at
# k 8/16/64, native float stage-1, and the serve p50/p99 under both kernel
# selections in one binary — (re)writes BENCH_6.json at the repo root
# (atomically: temp + rename). On a nightly toolchain add
# `--features simd` to measure the lane kernels; a stable build records an
# honest ≈1.0x scalar-fallback split and says so in the json's
# backend/simd_feature fields.
bench-simd:
	cd rust && CLSTM_BENCH_FAST=1 cargo bench --bench bench_simd $(SIMD_FEATURES)
	test -s BENCH_6.json && grep -q '"source": "native:' BENCH_6.json
	! test -e BENCH_6.json.tmp

# Sustained-overload serving benchmark (PR 8): closed-loop capacity probe,
# then an open-loop Poisson burst at ~2× that rate through the elastic
# 1..2-lane engine with a 50 ms queue-wait SLO — (re)writes BENCH_7.json
# at the repo root (atomically: temp + rename).
bench-overload:
	cd rust && CLSTM_BENCH_FAST=1 cargo bench --bench bench_pipeline
	test -s BENCH_7.json && grep -q '"shed_rate"' BENCH_7.json
	grep -q '"source": "native:' BENCH_7.json
	! test -e BENCH_7.json.tmp

# Fixed-point serving smoke test: a few utterances through the 16-bit
# datapath on 2 lanes. Assertions read the machine-readable metrics
# snapshot (stable keys, no prose greps, no jq): right document kind and
# schema, every utterance served, and a present, nonzero PER.
serve-fxp:
	cd rust && cargo run --release -- serve --backend fxp --replicas 2 --utts 4 \
		--metrics-json /tmp/clstm-serve-fxp.json | tee /tmp/clstm-serve-fxp.out
	grep -q '"kind": "clstm-metrics"' /tmp/clstm-serve-fxp.json
	grep -q '"schema_version": 1' /tmp/clstm-serve-fxp.json
	grep -q '"utterances": 4' /tmp/clstm-serve-fxp.json
	grep -Eq '"per_pct": [0-9]+(\.[0-9]+)?,?$$' /tmp/clstm-serve-fxp.json
	! grep -Eq '"per_pct": 0,?$$' /tmp/clstm-serve-fxp.json

# Stack-topology serving smoke test: the full bidirectional 2-layer Small
# model (4 chained segments) on the fxp datapath through 2 replicated
# topology instances; asserts PER is reported over the full stack and is
# nonzero.
serve-stack:
	cd rust && cargo run --release -- serve --model small --k 8 --backend fxp \
		--replicas 2 --utts 8 | tee /tmp/clstm-serve-stack.out
	grep -q "topology: 4 segment(s)" /tmp/clstm-serve-stack.out
	grep -E "workload PER: [0-9]+\.[0-9]+% \(full 2-layer stack\)" /tmp/clstm-serve-stack.out
	! grep -q "workload PER: 0\.00%" /tmp/clstm-serve-stack.out

# Sustained-overload serving smoke: a Poisson burst far past capacity on an
# elastic 1..2-lane engine with a queue-wait SLO. Assertions read the
# metrics snapshot's stable keys (no prose greps, no jq): a nonzero shed
# count AND `slo_met: true` — i.e. deadline-aware admission kept the
# *served* tail healthy instead of letting the backlog blow every
# utterance's deadline.
serve-overload:
	cd rust && cargo run --release -- serve --replicas 1..2 --utts 2000 \
		--arrival poisson --rate 100000 --slo-ms 50 \
		--metrics-json /tmp/clstm-serve-overload.json | tee /tmp/clstm-serve-overload.out
	grep -q '"slo_met": true' /tmp/clstm-serve-overload.json
	grep -Eq '"shed": [1-9][0-9]*,?$$' /tmp/clstm-serve-overload.json

# Fault-tolerance smoke: the overload scenario with seeded chaos on top.
# Seed 53 at rate 0.15 puts a single persistent fault on pool slot 0 —
# the initial lane's stage-3 executor — with every replacement slot
# clean, so the run must quarantine + respawn exactly that lane and retry
# its in-flight utterances. Assertions read the snapshot's `faults` block
# (nonzero restarts AND retries) and re-validate admission conservation
# (`served + shed == offered` with retries active) via `clstm trace-check`.
serve-chaos:
	cd rust && cargo run --release -- serve --replicas 1..2 --utts 2000 \
		--arrival poisson --rate 100000 --slo-ms 50 \
		--fault-inject 53:0.15:persistent \
		--metrics-json /tmp/clstm-serve-chaos.json | tee /tmp/clstm-serve-chaos.out
	grep -Eq '"restarts": [1-9][0-9]*,?$$' /tmp/clstm-serve-chaos.json
	grep -Eq '"retries": [1-9][0-9]*,?$$' /tmp/clstm-serve-chaos.json
	cd rust && cargo run --release -- trace-check \
		--metrics-json /tmp/clstm-serve-chaos.json | tee /tmp/clstm-serve-chaos-check.out
	grep -q "admission conservation ok" /tmp/clstm-serve-chaos-check.out

# End-to-end observability smoke: a 2-replica stacked fxp serve recording
# both artifacts — the Chrome span trace and the metrics snapshot — then
# `clstm trace-check` re-validating them (balanced spans, strictly
# monotonic per-track timestamps, snapshot schema, and utterance
# conservation trace ↔ snapshot).
serve-trace:
	cd rust && cargo run --release -- serve --model google --k 8 --backend fxp \
		--replicas 2 --utts 4 --trace /tmp/clstm-trace.json \
		--metrics-json /tmp/clstm-metrics.json
	cd rust && cargo run --release -- trace-check --trace /tmp/clstm-trace.json \
		--metrics-json /tmp/clstm-metrics.json
	! test -e /tmp/clstm-trace.json.tmp
	! test -e /tmp/clstm-metrics.json.tmp

# Static datapath verifier smoke: both paper-scale models through
# `clstm verify` at the default (range-analysis) format and at one
# explicit non-default format, plus the scheduler-graph pass (release
# mode: google-scale weight quantisation runs in the check). Non-zero
# exit on any E*/S* violation.
verify-datapath:
	cd rust && cargo run --release -- verify --model google --k 8
	cd rust && cargo run --release -- verify --model small --k 8
	cd rust && cargo run --release -- verify --model google --k 8 --q-format q4.11
	cd rust && cargo run --release -- verify --model small --k 8 --q-format q4.11

# JAX AOT lowering -> rust/artifacts/*.hlo.txt + manifest.json + golden
# bundle (enables the golden-vector integration tests and the PJRT backend).
artifacts:
	cd python && python -m compile.aot --out ../rust/artifacts

# Table 1 training sweep -> rust/artifacts/table1.json (PER column of
# `clstm table1` / bench_table1).
table1-per:
	cd python && python -m compile.train --out ../rust/artifacts/table1.json
