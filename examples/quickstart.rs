//! Quickstart: the C-LSTM serving stack in one page — zero artifacts.
//!
//! 1. Build a tiny block-circulant model with random weights.
//! 2. Run one step on the float engine and the bit-accurate 16-bit
//!    fixed-point engine and print their agreement (§4.2 quantisation).
//! 3. Drive the 3-stage serving pipeline on the **native backend** over
//!    three interleaved streams and check it against the engine frame for
//!    frame (the Fig 7 architecture in software).
//! 4. Serve a SynthTIMIT workload end to end (pipeline → classifier → PER).
//!
//! Run: `cargo run --release --example quickstart`
//!
//! (With `--features pjrt` and `make artifacts`, the same pipeline can run
//! the AOT-compiled HLO stages instead — see `examples/serve.rs` and
//! DESIGN.md.)

use clstm::coordinator::pipeline::ClstmPipeline;
use clstm::coordinator::server::{serve_workload, ServeOptions};
use clstm::lstm::activations::ActivationMode;
use clstm::lstm::cell_f32::CellF32;
use clstm::lstm::cell_fxp::CellFx;
use clstm::lstm::config::LstmSpec;
use clstm::lstm::weights::LstmWeights;
use clstm::num::fxp::Q;
use clstm::runtime::native::NativeBackend;
use clstm::util::prng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    let spec = LstmSpec::tiny(4);
    let weights = LstmWeights::random(&spec, 1234);
    println!(
        "model: tiny (k={}, in={}, hidden={}, proj={:?})",
        spec.k, spec.input_dim, spec.hidden_dim, spec.proj_dim
    );

    // --- [1] float vs bit-accurate fixed-point engine on one step.
    let mut rng = Xoshiro256::seed_from_u64(7);
    let x: Vec<f32> = (0..spec.input_dim)
        .map(|_| rng.uniform(-1.0, 1.0) as f32)
        .collect();
    let cell = CellF32::new(&spec, 0, &weights.layers[0][0], ActivationMode::Exact);
    let mut st = cell.zero_state();
    let y_f32 = cell.step(&x, &mut st);

    let fx = CellFx::new(&spec, 0, &weights.layers[0][0], Q::new(12));
    let mut stx = fx.zero_state();
    let y_fx = fx.step_f32(&x, &mut stx);
    let max_err_fx = y_f32
        .iter()
        .zip(&y_fx)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("f32 engine vs 16-bit fxp engine: max |err| = {max_err_fx:.2e} (§4.2 quantisation)");
    assert!(max_err_fx < 0.05);

    // --- [2] the 3-stage native pipeline over interleaved streams.
    let backend = NativeBackend::default();
    let mut pipe = ClstmPipeline::build(&backend, &weights)?;
    let utts: Vec<Vec<Vec<f32>>> = (0..3)
        .map(|_| {
            (0..6)
                .map(|_| {
                    (0..spec.input_dim)
                        .map(|_| rng.uniform(-1.0, 1.0) as f32)
                        .collect()
                })
                .collect()
        })
        .collect();
    let (outs, metrics) = pipe.run_utterances(&utts)?;
    // Reference: the plain engine, one stream at a time.
    let mut max_err_pipe = 0.0f32;
    for (u, frames) in utts.iter().enumerate() {
        let mut st = cell.zero_state();
        for (t, xf) in frames.iter().enumerate() {
            let want = cell.step(xf, &mut st);
            for (a, b) in want.iter().zip(&outs[u][t]) {
                max_err_pipe = max_err_pipe.max((a - b).abs());
            }
        }
    }
    println!(
        "native pipeline vs engine:       max |err| = {max_err_pipe:.2e}  ({})",
        metrics.summary()
    );
    assert!(max_err_pipe < 1e-4);
    drop(pipe);

    // --- [3] end-to-end serving: workload → engine → classifier → PER.
    let opts = ServeOptions {
        streams_per_lane: 3,
        ..ServeOptions::default()
    };
    let report = serve_workload(&backend, &weights, 8, &opts)?;
    println!("serve [{}]: {}", report.config, report.metrics.summary());
    println!("workload PER (random-init weights): {:.1}%", report.per);

    println!("\nquickstart OK — the serving pipeline runs end to end on the native backend.");
    Ok(())
}
