//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load the AOT-compiled tiny model artifact (built by `make artifacts`
//!    from the JAX/Pallas layers).
//! 2. Prepare spectral weights in Rust from the golden weight file.
//! 3. Execute one LSTM step through PJRT and check it against the JAX
//!    golden vector.
//! 4. Run the same step on the pure-Rust engines (float and bit-accurate
//!    16-bit fixed point) and print the agreement.
//!
//! Run: `cargo run --release --example quickstart`

use clstm::lstm::activations::ActivationMode;
use clstm::lstm::cell_f32::CellF32;
use clstm::lstm::cell_fxp::CellFx;
use clstm::lstm::weights::LstmWeights;
use clstm::num::fxp::Q;
use clstm::runtime::artifact::{ArtifactDir, SpectralBundle};
use clstm::runtime::client::Runtime;
use clstm::util::json::Json;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let art = ArtifactDir::open(Path::new("artifacts"))
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    let cfg = art.config("tiny_fft4").expect("tiny config");
    let weights = LstmWeights::load(art.golden_weights.as_ref().unwrap())?;
    let golden = Json::parse(&std::fs::read_to_string(
        art.golden_vectors.as_ref().unwrap(),
    )?)
    .map_err(|e| anyhow::anyhow!("golden: {e}"))?;
    let spec = weights.spec.clone();
    println!(
        "model: tiny (k={}, in={}, hidden={}, proj={:?})",
        spec.k, spec.input_dim, spec.hidden_dim, spec.proj_dim
    );

    // --- Layer 3 drives the Layer-2/Layer-1 artifact through PJRT.
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let exe = rt.load_hlo_text(&art.path_of(&cfg.step))?;
    let bundle = SpectralBundle::from_weights(&weights, 0, 0);

    let x: Vec<f32> = golden.get("step_x").unwrap().to_f32_vec().unwrap();
    let want_y: Vec<f32> = golden.get("step_y").unwrap().to_f32_vec().unwrap();
    let out_pad = spec.pad(spec.out_dim());
    let (y0, c0) = (vec![0.0f32; out_pad], vec![0.0f32; spec.hidden_dim]);
    let gd: Vec<i64> = bundle.gates_shape.iter().map(|&d| d as i64).collect();
    let pd: Vec<i64> = bundle.proj_shape.iter().map(|&d| d as i64).collect();
    let h = spec.hidden_dim as i64;
    let outs = exe.run_f32(&[
        (&bundle.gates_re, &gd),
        (&bundle.gates_im, &gd),
        (&bundle.bias, &[4, h]),
        (&bundle.peep, &[3, h]),
        (&bundle.proj_re, &pd),
        (&bundle.proj_im, &pd),
        (&x, &[1, spec.input_dim as i64]),
        (&y0, &[1, out_pad as i64]),
        (&c0, &[1, h]),
    ])?;
    let y_pjrt = &outs[0];
    let max_err_pjrt = y_pjrt
        .iter()
        .zip(&want_y)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("PJRT step vs JAX golden:   max |err| = {max_err_pjrt:.2e}");

    // --- Same step on the pure-Rust engines.
    let cell = CellF32::new(&spec, 0, &weights.layers[0][0], ActivationMode::Exact);
    let mut st = cell.zero_state();
    let y_rust = cell.step(&x, &mut st);
    let max_err_rust = y_rust
        .iter()
        .zip(&want_y)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("Rust f32 engine vs golden: max |err| = {max_err_rust:.2e}");

    let fx = CellFx::new(&spec, 0, &weights.layers[0][0], Q::new(12));
    let mut stx = fx.zero_state();
    let y_fx = fx.step_f32(&x, &mut stx);
    let max_err_fx = y_fx
        .iter()
        .zip(&want_y)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("16-bit fxp engine vs golden: max |err| = {max_err_fx:.2e} (§4.2 quantisation)");

    assert!(max_err_pjrt < 1e-4 && max_err_rust < 2e-4 && max_err_fx < 0.05);
    println!("\nquickstart OK — all three execution paths agree.");
    Ok(())
}
