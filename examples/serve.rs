//! Serving demo at Google-LSTM scale: the replicated **stack** engine
//! under sustained load on the native backend (k=8 spectral weights, 1024
//! hidden, both stacked layers chained per Fig 6b — layer 1 consumes frame
//! t while layer 0 computes t+1). The spectra of every segment are
//! prepared **once** and shared by every topology instance; admission is
//! continuous (no wave barrier), so the same workload is served with 1
//! instance and with N instances and the speedup printed.
//!
//! Run: `cargo run --release --example serve [-- n_utts [replicas]]`

use clstm::coordinator::batcher::QueuedUtterance;
use clstm::coordinator::engine::EngineConfig;
use clstm::coordinator::metrics::Metrics;
use clstm::coordinator::topology::StackEngine;
use clstm::data::synth::{SynthConfig, SynthTimit};
use clstm::lstm::config::LstmSpec;
use clstm::lstm::weights::LstmWeights;
use clstm::runtime::native::NativeBackend;

/// Serve `utts` through a stack engine with `replicas` topology instances;
/// return metrics (including per-segment occupancy).
fn run_engine(
    backend: &NativeBackend,
    weights: &LstmWeights,
    utts: &[QueuedUtterance],
    replicas: usize,
) -> anyhow::Result<Metrics> {
    let mut engine = StackEngine::build(
        backend,
        weights,
        EngineConfig {
            replicas,
            ..EngineConfig::default()
        },
    )?;
    let mut metrics = Metrics::default();
    let t0 = std::time::Instant::now();
    // Continuous admission: keep every instance fed, drain as streams
    // retire.
    for c in engine.serve_all(utts.iter().cloned())? {
        metrics.record_completion(&c);
    }
    metrics.wall = t0.elapsed();
    metrics.set_segments(engine.segment_stats());
    Ok(metrics)
}

fn main() -> anyhow::Result<()> {
    let n_utts: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let replicas: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    // Random weights: this demo measures the serving path, not accuracy.
    let spec = LstmSpec::google(8);
    let weights = LstmWeights::random(&spec, 42);

    let backend = NativeBackend::default();
    println!("google k=8 on the native backend (spectra prepared once, shared by all instances)");
    println!(
        "topology: {}",
        clstm::coordinator::topology::StackTopology::compile(&spec).describe()
    );

    let gen = SynthTimit::new(SynthConfig::google());
    let utts: Vec<QueuedUtterance> = (0..n_utts)
        .map(|i| {
            let mut u = gen.utterance(3, i as u64);
            u.frames.truncate(24); // short utterances: demo-sized
            for f in u.frames.iter_mut() {
                f.truncate(spec.input_dim);
                f.resize(spec.input_dim, 0.0);
            }
            QueuedUtterance::new(i as u64, u.frames)
        })
        .collect();

    let single = run_engine(&backend, &weights, &utts, 1)?;
    println!("  1 lane : {}", single.summary());
    let multi = run_engine(&backend, &weights, &utts, replicas)?;
    println!("  {replicas} lanes: {}", multi.summary());
    if single.fps() > 0.0 {
        println!(
            "\nreplica scaling: {:.2}× throughput with {replicas} lanes",
            multi.fps() / single.fps()
        );
    }
    println!(
        "(for the FPGA-side throughput of this design — 195k FPS on KU060 — see `clstm table3`; \
         for PJRT execution of the AOT artifacts build with --features pjrt)"
    );
    Ok(())
}
