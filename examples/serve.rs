//! Serving demo at Google-LSTM scale: the replicated engine under sustained
//! load on the native backend (k=8 spectral weights, 1024 hidden, 672-wide
//! fused input). The spectra are prepared **once** and shared by every
//! lane; admission is continuous (no wave barrier), so the same workload is
//! served with 1 lane and with N lanes and the speedup printed.
//!
//! Run: `cargo run --release --example serve [-- n_utts [replicas]]`

use clstm::coordinator::batcher::QueuedUtterance;
use clstm::coordinator::engine::{EngineConfig, ServeEngine};
use clstm::coordinator::metrics::Metrics;
use clstm::data::synth::{SynthConfig, SynthTimit};
use clstm::lstm::config::LstmSpec;
use clstm::lstm::weights::LstmWeights;
use clstm::runtime::native::NativeBackend;

/// Serve `utts` through an engine with `replicas` lanes; return metrics.
fn run_engine(
    backend: &NativeBackend,
    weights: &LstmWeights,
    utts: &[QueuedUtterance],
    replicas: usize,
) -> anyhow::Result<Metrics> {
    let mut engine = ServeEngine::build(
        backend,
        weights,
        EngineConfig {
            replicas,
            ..EngineConfig::default()
        },
    )?;
    let mut metrics = Metrics::default();
    let t0 = std::time::Instant::now();
    // Continuous admission: keep every lane fed, drain as streams retire.
    for c in engine.serve_all(utts.iter().cloned())? {
        metrics.record_completion(&c);
    }
    metrics.wall = t0.elapsed();
    Ok(metrics)
}

fn main() -> anyhow::Result<()> {
    let n_utts: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let replicas: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    // Random weights: this demo measures the serving path, not accuracy.
    let spec = LstmSpec::google(8);
    let weights = LstmWeights::random(&spec, 42);

    let backend = NativeBackend::default();
    println!("google k=8 on the native backend (spectra prepared once, shared by all lanes)");

    let gen = SynthTimit::new(SynthConfig::google());
    let utts: Vec<QueuedUtterance> = (0..n_utts)
        .map(|i| {
            let mut u = gen.utterance(3, i as u64);
            u.frames.truncate(24); // short utterances: demo-sized
            for f in u.frames.iter_mut() {
                f.truncate(spec.input_dim);
                f.resize(spec.input_dim, 0.0);
            }
            QueuedUtterance::new(i as u64, u.frames)
        })
        .collect();

    let single = run_engine(&backend, &weights, &utts, 1)?;
    println!("  1 lane : {}", single.summary());
    let multi = run_engine(&backend, &weights, &utts, replicas)?;
    println!("  {replicas} lanes: {}", multi.summary());
    if single.fps() > 0.0 {
        println!(
            "\nreplica scaling: {:.2}× throughput with {replicas} lanes",
            multi.fps() / single.fps()
        );
    }
    println!(
        "(for the FPGA-side throughput of this design — 195k FPS on KU060 — see `clstm table3`; \
         for PJRT execution of the AOT artifacts build with --features pjrt)"
    );
    Ok(())
}
