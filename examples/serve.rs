//! Serving demo at Google-LSTM scale: sustained throughput of the 3-stage
//! pipeline with batcher-managed admission and backpressure, on the native
//! backend (k=8 spectral weights, 1024 hidden, 672-wide fused input).
//!
//! Run: `cargo run --release --example serve [-- n_utts]`

use clstm::coordinator::batcher::{Batcher, QueuedUtterance};
use clstm::coordinator::metrics::Metrics;
use clstm::coordinator::pipeline::ClstmPipeline;
use clstm::data::synth::{SynthConfig, SynthTimit};
use clstm::lstm::config::LstmSpec;
use clstm::lstm::weights::LstmWeights;
use clstm::runtime::native::NativeBackend;

fn main() -> anyhow::Result<()> {
    let n_utts: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6);
    // Random weights: this demo measures the serving path, not accuracy.
    let spec = LstmSpec::google(8);
    let weights = LstmWeights::random(&spec, 42);

    let backend = NativeBackend::default();
    println!("building google k=8 stages on the native backend (precomputing spectra)...");
    let mut pipe = ClstmPipeline::build(&backend, &weights)?;

    let gen = SynthTimit::new(SynthConfig::google());
    let mut batcher = Batcher::new(n_utts, 4);
    for i in 0..n_utts {
        let mut u = gen.utterance(3, i as u64);
        u.frames.truncate(24); // short utterances: demo-sized
        for f in u.frames.iter_mut() {
            f.truncate(spec.input_dim);
            f.resize(spec.input_dim, 0.0);
        }
        batcher.offer(QueuedUtterance {
            id: i as u64,
            frames: u.frames,
        });
    }

    let mut total = Metrics::default();
    while !batcher.is_empty() {
        let wave = batcher.next_wave();
        let frames: Vec<_> = wave.iter().map(|u| u.frames.clone()).collect();
        println!("  wave of {} utterances ...", frames.len());
        let (_outs, m) = pipe.run_utterances(&frames)?;
        println!("    {}", m.summary());
        total.frames += m.frames;
        total.utterances += m.utterances;
        total.wall += m.wall;
        total.frame_latency_us.extend(m.frame_latency_us);
    }
    println!("\noverall: {}", total.summary());
    println!(
        "(for the FPGA-side throughput of this design — 195k FPS on KU060 — see `clstm table3`; \
         for PJRT execution of the AOT artifacts build with --features pjrt)"
    );
    Ok(())
}
