//! End-to-end ASR driver — the full-system validation run (see DESIGN.md
//! for the experiment index).
//!
//! Pipeline: SynthTIMIT workload → Layer-3 coordinator (3-stage pipeline on
//! the native backend, Fig 7) → classifier → PER; then the same workload
//! through the bit-accurate 16-bit fixed-point engine to measure the §4.2
//! quantisation cost; then the analytical/simulated FPGA numbers for the
//! same model so all metrics of the paper appear in one report.
//!
//! Run: `cargo run --release --example asr_pipeline`

use clstm::coordinator::server::{serve_workload, ServeOptions};
use clstm::data::per::phone_error_rate;
use clstm::data::synth::{SynthConfig, SynthTimit};
use clstm::dse::DesignPoint;
use clstm::fpga_sim::simulate;
use clstm::lstm::activations::ActivationMode;
use clstm::lstm::config::LstmSpec;
use clstm::lstm::sequence::{StackF32, StackFx};
use clstm::lstm::weights::LstmWeights;
use clstm::num::fxp::Q;
use clstm::perfmodel::platform::Platform;
use clstm::runtime::native::NativeBackend;

fn main() -> anyhow::Result<()> {
    println!("=== C-LSTM end-to-end ASR pipeline ===\n");

    // ---------- Part 1: serve through the replicated native engine -------
    let weights = LstmWeights::random(&LstmSpec::tiny(4), 1234);
    println!(
        "[1] serving 16 SynthTIMIT utterances through the replicated native engine \
         (tiny, k=4, 2 lanes):"
    );
    let opts = ServeOptions {
        replicas: 2,
        ..ServeOptions::default()
    };
    let report = serve_workload(&NativeBackend::default(), &weights, 16, &opts)?;
    println!("    {} ({} lanes)", report.metrics.summary(), report.replicas);
    println!("    workload PER (random-init weights): {:.1}%\n", report.per);

    // ---------- Part 2: quantisation study on a trained-scale model ------
    // Float vs bit-accurate fixed-point engines on the same utterances —
    // the §4.2 "16-bit is accurate enough" claim, measured end to end.
    println!("[2] float vs 16-bit fixed-point engines (PWL activations, Q3.12):");
    let spec = LstmSpec {
        hidden_dim: 64,
        proj_dim: Some(32),
        input_dim: 24,
        num_classes: 12,
        ..LstmSpec::tiny(4)
    };
    let w2 = LstmWeights::random(&spec, 77);
    let synth = SynthTimit::new(SynthConfig {
        n_phones: spec.num_classes,
        base_dim: spec.input_dim / 3 - 1,
        mean_frames: 60,
        ..SynthConfig::tiny()
    });
    let utts = synth.batch(5, 12);
    let frames: Vec<Vec<Vec<f32>>> = utts
        .iter()
        .map(|u| {
            u.frames
                .iter()
                .map(|f| {
                    let mut v = f.clone();
                    v.resize(spec.input_dim, 0.0);
                    v
                })
                .collect()
        })
        .collect();
    let refs: Vec<Vec<usize>> = utts.iter().map(|u| u.phone_seq()).collect();
    let float = StackF32::new(&w2, ActivationMode::Pwl);
    let fxp = StackFx::new(&w2, Q::new(12));
    let t0 = std::time::Instant::now();
    let f_hyps: Vec<Vec<usize>> = frames.iter().map(|f| float.decode(f)).collect();
    let t_float = t0.elapsed();
    let t0 = std::time::Instant::now();
    let x_hyps: Vec<Vec<usize>> = frames.iter().map(|f| fxp.decode(f)).collect();
    let t_fxp = t0.elapsed();
    let (mut agree, mut total) = (0usize, 0usize);
    for (a, b) in f_hyps.iter().zip(&x_hyps) {
        agree += a.iter().zip(b).filter(|(x, y)| x == y).count();
        total += a.len();
    }
    println!(
        "    PER float {:.2}%  |  PER fxp {:.2}%  (Δ {:+.2})",
        phone_error_rate(&f_hyps, &refs),
        phone_error_rate(&x_hyps, &refs),
        phone_error_rate(&x_hyps, &refs) - phone_error_rate(&f_hyps, &refs)
    );
    println!(
        "    framewise agreement {:.1}%  |  engine time: float {:.0}ms, fxp {:.0}ms\n",
        100.0 * agree as f64 / total as f64,
        t_float.as_secs_f64() * 1e3,
        t_fxp.as_secs_f64() * 1e3
    );

    // ---------- Part 3: the FPGA-side numbers for the served model -------
    println!("[3] synthesis-flow numbers for the Google LSTM (the Table 3 design):");
    for k in [8usize, 16] {
        let p = DesignPoint::evaluate(&LstmSpec::google(k), &Platform::ku060());
        let sim = simulate(&p.schedule, 64);
        println!(
            "    FFT{k}: analytical {:>7.0} FPS / {:>5.1} µs latency  |  simulated II {} cycles ({} FPS)  |  {:.0} FPS/W",
            p.perf.fps,
            p.perf.latency_us,
            sim.ii_cycles,
            (200e6 / sim.ii_cycles as f64) as u64,
            p.fps_per_watt
        );
    }
    println!("\nasr_pipeline OK");
    Ok(())
}
