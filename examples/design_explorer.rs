//! Design-space exploration walkthrough: the §5 automatic synthesis flow
//! from model spec to generated HLS code, across both platforms.
//!
//! Run: `cargo run --release --example design_explorer`

use clstm::dse::{explore, pareto};
use clstm::graph::builder::build_layer_graph;
use clstm::hlscodegen::generate_design;
use clstm::lstm::config::LstmSpec;
use clstm::perfmodel::platform::Platform;
use clstm::report::Table;
use clstm::schedule::algorithm1::schedule;
use clstm::schedule::replication::enumerate_replication;

fn main() -> anyhow::Result<()> {
    // Table 2 — the platforms.
    let mut t2 = Table::new(
        "Table 2 — FPGA platforms",
        &["FPGA", "DSP", "BRAM", "LUT", "FF", "process"],
    );
    for p in [Platform::ku060(), Platform::adm7v3()] {
        t2.row(vec![
            p.name.to_string(),
            p.dsp.to_string(),
            p.bram36.to_string(),
            p.lut.to_string(),
            p.ff.to_string(),
            format!("{}nm", p.process_nm),
        ]);
    }
    t2.print();

    // Sweep both models × both platforms.
    for (label, base) in [("Google LSTM", LstmSpec::google(1)), ("Small LSTM", LstmSpec::small(1))] {
        for plat in [Platform::ku060(), Platform::adm7v3()] {
            let pts = explore(&base, &plat, &[2, 4, 8, 16]);
            println!("\n{label} on {} (KU060-bounded budget):", plat.name);
            println!(
                "  {:>4} {:>11} {:>11} {:>8} {:>9} {:>7} {:>7}",
                "k", "FPS", "latency µs", "power W", "FPS/W", "DSP%", "BRAM%"
            );
            for p in &pts {
                println!(
                    "  {:>4} {:>11.0} {:>11.2} {:>8.1} {:>9.0} {:>7.1} {:>7.1}",
                    p.spec.k,
                    p.perf.fps,
                    p.perf.latency_us,
                    p.power_w,
                    p.fps_per_watt,
                    p.utilisation.dsp,
                    p.utilisation.bram
                );
            }
            let front = pareto(&pts);
            println!(
                "  pareto (FPS vs power): {:?}",
                front.iter().map(|p| p.spec.k).collect::<Vec<_>>()
            );
        }
    }

    // Generate the HLS design for the headline configuration.
    let spec = LstmSpec::google(8);
    let plat = Platform::ku060();
    let g = build_layer_graph(&spec, 0);
    let s = enumerate_replication(schedule(&g, &plat.budget()), &plat.budget());
    let src = generate_design(&s, "google_fft8");
    let out = "target/google_fft8_generated.cpp";
    std::fs::create_dir_all("target")?;
    std::fs::write(out, &src)?;
    println!(
        "\ngenerated HLS C++ for google_fft8 ({} lines) -> {out}",
        src.lines().count()
    );
    println!("schedule:\n{}", s.describe());
    Ok(())
}
